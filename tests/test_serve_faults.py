"""Serving fault-tolerance layer (ISSUE 10): shared injection registry,
admission control, deadlines, degradation ladder, livelock diagnosis, and
a seeded chaos test of the scheduler invariants under injected failure.

The clean engine (no injections) is the oracle throughout: an injected run
must either produce the same greedy tokens or retire the affected request
with a meaningful finish_reason — never garbage tokens, never a leak.
"""
import warnings

import jax
import numpy as np
import pytest

from repro import injection
from repro.configs import get_reduced
from repro.serve import (
    Engine,
    LivelockError,
    Rejected,
    Request,
    ServeConfig,
    ServeFaultPlan,
    inject_paged_kernel_failure,
)
from repro.serve.faults import CLOCK_POINT

pytestmark = pytest.mark.filterwarnings("ignore")


def _mk(arch="gpt_small", **sc_kw):
    cfg = get_reduced(arch)
    params, _ = cfg.init(jax.random.PRNGKey(0))
    return cfg, params, ServeConfig(**sc_kw)


def _prompt(n, vocab, seed=1):
    return np.asarray(jax.random.randint(
        jax.random.PRNGKey(seed), (n,), 0, vocab))


def _invariants(eng):
    """No slot double-use, no page mapped twice, table agrees with pool
    ownership — checked live between scheduler steps."""
    sched = eng.scheduler
    seen = {}
    for slot in range(sched.n_slots):
        rid = sched.slot_rid[slot]
        row = sched.table[slot]
        if rid is None:
            assert not row.any(), f"empty slot {slot} has mapped pages"
            continue
        for pg in row[row != 0]:
            assert pg not in seen, f"page {pg} mapped by slots {seen[pg]},{slot}"
            seen[int(pg)] = slot
            assert eng.pool.owner(int(pg)) == rid


class TestInjectionRegistry:
    def test_fire_without_hook_is_noop(self):
        assert injection.fire("test.nothing", 1, 2) is None

    def test_installed_restores_previous_hook(self):
        with injection.installed("test.point", lambda: "outer"):
            with injection.installed("test.point", lambda: "inner"):
                assert injection.fire("test.point") == "inner"
            assert injection.fire("test.point") == "outer"
        assert injection.get("test.point") is None

    def test_call_counter_fails_on_schedule(self):
        hook, state = injection.call_counter(
            (2,), lambda n: RuntimeError(f"boom #{n}"))
        hook()
        with pytest.raises(RuntimeError, match="boom #2"):
            hook()
        hook()
        assert state == {"calls": 3, "failed": 1}


class TestAdmissionControl:
    def test_queue_full_rejects_without_exception(self):
        cfg, params, sc = _mk(max_seq=32, page_size=4, max_queue=1)
        eng = Engine(cfg, params, sc)
        first = eng.submit(Request(prompt=_prompt(4, cfg.vocab_size)))
        assert isinstance(first, int)
        verdict = eng.submit(Request(prompt=_prompt(4, cfg.vocab_size)))
        assert isinstance(verdict, Rejected)
        assert verdict.reason == "queue_full"
        assert verdict.queue_depth == 1
        m = eng.metrics()
        assert m.rejected_queue == 1 and m.rejected == 1

    def test_pool_pressure_rejects_on_projected_demand(self):
        # capacity 8 pages, watermark 0.5 -> 4 pages; each request projects
        # ceil((8 prompt + 8 new) / 4) = 4 pages, so the second must bounce.
        cfg, params, sc = _mk(max_seq=32, page_size=4, pool_pages=9,
                              max_new_tokens=8, admit_watermark=0.5)
        eng = Engine(cfg, params, sc)
        first = eng.submit(Request(prompt=_prompt(8, cfg.vocab_size)))
        assert isinstance(first, int)
        verdict = eng.submit(Request(prompt=_prompt(8, cfg.vocab_size)))
        assert isinstance(verdict, Rejected)
        assert verdict.reason == "pool_pressure"
        assert verdict.projected_pages == 8 > 0.5 * verdict.pool_capacity
        assert eng.metrics().rejected_pool == 1

    def test_impossible_request_still_raises(self):
        cfg, params, sc = _mk(max_seq=16, page_size=4, pool_pages=3)
        eng = Engine(cfg, params, sc)
        with pytest.raises(ValueError, match="pool"):
            eng.submit(Request(prompt=_prompt(8, cfg.vocab_size)))


class TestDeadlines:
    def test_queued_request_past_deadline_is_dropped(self):
        cfg, params, sc = _mk(max_seq=32, page_size=4, max_slots=1,
                              max_new_tokens=3)
        eng = Engine(cfg, params, sc)
        r0 = eng.submit(Request(prompt=_prompt(4, cfg.vocab_size)))
        r1 = eng.submit(Request(prompt=_prompt(4, cfg.vocab_size),
                                deadline_s=0.0))
        done = eng.run_until_drained()
        assert done[r1].finish_reason == "deadline"
        assert len(done[r1].tokens) == 0
        assert done[r0].finish_reason == "length"
        # r1 never reached a slot or the device
        assert eng.scheduler.admitted == 1
        assert eng.metrics().deadline_expired == 1
        assert eng.pool.used_pages == 0

    def test_active_request_retires_on_stalled_clock(self):
        cfg, params, sc = _mk(max_seq=48, page_size=4, max_new_tokens=8)
        eng = Engine(cfg, params, sc)
        rid = eng.submit(Request(prompt=_prompt(4, cfg.vocab_size),
                                 deadline_s=60.0))
        plan = ServeFaultPlan(stall_steps=(2,), stall_s=120.0)
        with plan.install(eng):
            done = eng.run_until_drained()
        c = done[rid]
        assert c.finish_reason == "deadline"
        assert 0 < len(c.tokens) < 8       # partial progress returned
        m = eng.metrics()
        assert m.deadline_expired == 1 and m.injected_stalls == 1
        assert eng.pool.used_pages == 0


class TestDegradation:
    def test_kernel_failure_degrades_with_token_parity(self):
        cfg, params, sc = _mk(max_seq=48, page_size=4, max_new_tokens=6,
                              prefill_chunk=4)
        prompt = _prompt(6, cfg.vocab_size)
        clean_eng = Engine(cfg, params, sc)
        rid = clean_eng.submit(Request(prompt=prompt))
        clean = clean_eng.run_until_drained()[rid].tokens

        eng = Engine(cfg, params, sc)
        rid = eng.submit(Request(prompt=prompt))
        # dispatch 1 = first prefill chunk, dispatch 4 = a decode step
        with inject_paged_kernel_failure(fail_on=(1, 4)) as state:
            done = eng.run_until_drained()
        assert state["failed"] == 2
        m = eng.metrics()
        assert m.degraded_steps == 2
        assert done[rid].finish_reason == "length"
        np.testing.assert_array_equal(done[rid].tokens, clean)

    def test_genuine_nan_logits_retire_not_crash(self):
        cfg, params, sc = _mk(max_seq=32, page_size=4, max_new_tokens=4)
        # Corrupt one embedding row; with tied embeddings every logit row
        # grows a NaN column, so the health tap must fire at prefill.
        bad = dict(params)
        emb = np.array(bad["embed"], np.float32)
        emb[0, :] = np.nan
        bad["embed"] = jax.numpy.asarray(emb)
        eng = Engine(cfg, bad, sc)
        rid = eng.submit(Request(prompt=_prompt(4, cfg.vocab_size)))
        done = eng.run_until_drained()
        assert done[rid].finish_reason == "nan"
        assert len(done[rid].tokens) == 0
        assert eng.metrics().nan_retired == 1
        assert eng.pool.used_pages == 0

    def test_injected_poison_isolates_one_request(self):
        cfg, params, sc = _mk(max_seq=48, page_size=4, max_new_tokens=6)
        prompts = [_prompt(5, cfg.vocab_size, seed=s) for s in (1, 2)]
        clean_eng = Engine(cfg, params, sc)
        crids = [clean_eng.submit(Request(prompt=p)) for p in prompts]
        clean = clean_eng.run_until_drained()

        eng = Engine(cfg, params, sc)
        rids = [eng.submit(Request(prompt=p)) for p in prompts]
        plan = ServeFaultPlan(poison_rids=(rids[1],), poison_after=2)
        with plan.install(eng):
            done = eng.run_until_drained()
        # the clean slot never notices its neighbour's poisoning
        assert done[rids[0]].finish_reason == "length"
        np.testing.assert_array_equal(done[rids[0]].tokens,
                                      clean[crids[0]].tokens)
        poisoned = done[rids[1]]
        assert poisoned.finish_reason == "nan"
        assert len(poisoned.tokens) == 2
        np.testing.assert_array_equal(poisoned.tokens,
                                      clean[crids[1]].tokens[:2])
        m = eng.metrics()
        assert m.nan_retired == 1 and m.injected_poison == 1


class TestLivelock:
    def test_wedged_pool_raises_diagnosable_livelock(self):
        cfg, params, sc = _mk(max_seq=32, page_size=4, pool_pages=5,
                              max_new_tokens=4, livelock_patience=4)
        eng = Engine(cfg, params, sc)
        # Hold every free page permanently: the queued request can never
        # admit, so the drain loop must back off and then diagnose.
        held = eng.pool.reserve(eng.pool.capacity)
        rid = eng.submit(Request(prompt=_prompt(4, cfg.vocab_size)))
        with pytest.raises(LivelockError) as ei:
            eng.run_until_drained()
        err = ei.value
        assert err.queued_rids == (rid,)
        assert err.metrics.livelock_backoffs == 4
        assert err.metrics.free_pages == 0
        for needle in ("free_pages=0", f"queue=[{rid}]", "slot_rids"):
            assert needle in str(err)
        assert isinstance(err, RuntimeError)   # old broad handlers still fire
        eng.pool.unreserve(held)

    def test_transient_pressure_recovers_without_error(self):
        cfg, params, sc = _mk(max_seq=32, page_size=4, pool_pages=5,
                              max_new_tokens=4, livelock_patience=12)
        eng = Engine(cfg, params, sc)
        # Squeeze the whole pool for a few steps, then release: backoff
        # must bridge the window and the request must still complete.
        plan = ServeFaultPlan(squeeze_window=(0, 4),
                              squeeze_pages=eng.pool.capacity)
        rid = eng.submit(Request(prompt=_prompt(4, cfg.vocab_size)))
        with plan.install(eng):
            done = eng.run_until_drained()
        assert done[rid].finish_reason == "length"
        m = eng.metrics()
        assert m.livelock_backoffs >= 1
        assert eng.pool.used_pages == 0


class TestWarnOnce:
    def test_truncation_warns_once_but_counts_every_time(self):
        cfg, params, sc = _mk(max_seq=8, page_size=4, max_new_tokens=32)
        eng = Engine(cfg, params, sc)
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            eng.submit(Request(prompt=_prompt(4, cfg.vocab_size)))
            eng.submit(Request(prompt=_prompt(4, cfg.vocab_size)))
        truncs = [x for x in w if "truncating" in str(x.message)]
        assert len(truncs) == 1
        assert eng.counters.truncated_max_new == 2
        assert eng.counters.warned_codes == ("truncate_max_new",)


class TestChaos:
    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_random_faults_preserve_scheduler_invariants(self, seed):
        """Seeded random workload + random fault plan on a near-capacity
        pool: every scheduler step upholds the ownership invariants, every
        accepted request completes, and the pool drains to zero."""
        rng = np.random.default_rng(seed)
        cfg, params, sc = _mk(max_seq=32, page_size=4, max_slots=3,
                              pool_pages=11, max_new_tokens=5,
                              prefill_chunk=4)
        eng = Engine(cfg, params, sc)
        n_req = 5
        prompts = [rng.integers(0, cfg.vocab_size,
                                size=int(rng.integers(3, 10)))
                   for _ in range(n_req)]
        plan = ServeFaultPlan(
            kernel_fail_steps=tuple(
                int(x) for x in rng.choice(12, size=2, replace=False)),
            prefill_fail_chunks=(int(rng.integers(0, 4)),),
            poison_rids=(int(rng.integers(0, n_req)),),
            poison_after=int(rng.integers(1, 4)),
            squeeze_window=(1, 5),
            squeeze_pages=int(rng.integers(0, 5)),
        )
        with plan.install(eng):
            rids = [eng.submit(Request(prompt=p)) for p in prompts]
            assert all(isinstance(r, int) for r in rids)
            steps = 0
            while eng.scheduler.queue or eng.scheduler.active_slots():
                eng.step()
                _invariants(eng)
                steps += 1
                assert steps < 200, "chaos run failed to drain"
        done = eng.completions()
        assert set(done) == set(rids)
        assert all(c.finish_reason in ("eos", "length", "nan")
                   for c in done.values())
        assert eng.pool.used_pages == 0
        assert eng.pool.alloc_count == eng.pool.free_count
        m = eng.metrics()
        assert m.degraded_steps >= 1       # at least one injection landed
