"""Sharding logic + a small-mesh SPMD integration test (8 host devices via a
subprocess so the main pytest process keeps its single real CPU device)."""
import json
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.sharding.logical import ShardingContext, default_rules


class FakeMesh:
    """Just enough of a Mesh for spec_for tests (single-device env)."""

    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(self.shape)


def _ctx(shape=(("data", 4), ("model", 2))):
    return ShardingContext.__new__(ShardingContext), shape


def make_ctx(shape=(("data", 4), ("model", 2))):
    ctx = ShardingContext.__new__(ShardingContext)
    ctx.mesh = FakeMesh(shape)
    ctx.rules = default_rules(ctx.mesh)
    return ctx


class TestSpecFor:
    def test_param_specs(self):
        ctx = make_ctx()
        assert ctx.spec_for(("embed", "mlp"), (8, 16)) == P("data", "model")
        assert ctx.spec_for(("vocab", "embed"), (32, 8)) == P("model", "data")

    def test_divisibility_fallback_replicates(self):
        ctx = make_ctx()
        # 7 not divisible by model=2 -> replicated without allow_pad
        assert ctx.spec_for(("embed", "mlp"), (8, 7)) == P("data", None)
        # with allow_pad (activations), 7 >= 2 so padding is allowed
        assert ctx.spec_for(("embed", "mlp"), (8, 7), allow_pad=True) == P("data", "model")
        # smaller than axis: never padded
        assert ctx.spec_for((None, "mlp"), (8, 1), allow_pad=True) == P(None, None)

    def test_axis_used_once(self):
        ctx = make_ctx()
        # both 'heads' and 'mlp' map to model; second one must fall to None
        spec = ctx.spec_for(("heads", "mlp"), (4, 4))
        assert spec == P("model", None)

    def test_pod_axis_in_batch(self):
        ctx = make_ctx((("pod", 2), ("data", 2), ("model", 2)))
        assert ctx.spec_for(("batch", None), (8, 3)) == P(("pod", "data"), None)

    def test_structural_layers_never_sharded(self):
        ctx = make_ctx()
        assert ctx.spec_for(("layers", "embed", "mlp"), (12, 8, 16)) == P(None, "data", "model")


class TestOptStateSpecs:
    def test_slim_nu_masked(self):
        from repro.core.slim_adam import scale_by_slim_adam
        from repro.sharding.state_shardings import opt_state_specs

        params = {"w": jax.ShapeDtypeStruct((8, 16), jnp.float32)}
        spec_tree = {"w": P("data", "model")}
        tx = scale_by_slim_adam({"w": (1,)})
        state = jax.eval_shape(tx.init, params)
        specs = opt_state_specs(state, params, spec_tree)
        assert specs.mu["w"] == P("data", "model")      # full moment: param spec
        assert specs.nu["w"] == P("data", None)          # collapsed dim replicated
        assert specs.count == P()


SPMD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_reduced
from repro.core import rules_as_tree, table3_rules
from repro.core.slim_adam import slim_adam
from repro.sharding.logical import ShardingContext, param_specs, use_sharding
from repro.sharding.state_shardings import opt_state_specs
from repro.train.step import make_train_step

mesh = jax.make_mesh((4, 2), ("data", "model"))
cfg = get_reduced("smollm_135m")
ctx = ShardingContext(mesh)
with use_sharding(ctx):
    params, meta = cfg.init(jax.random.PRNGKey(0))
    p_specs = param_specs(meta, params)
    p_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs, is_leaf=lambda x: isinstance(x, P))
    rules = table3_rules(meta)
    tx = slim_adam(1e-3, rules_as_tree(rules, params, meta))
    opt = tx.init(params)
    o_specs = opt_state_specs(jax.eval_shape(lambda: opt), params, p_specs)
    o_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), o_specs, is_leaf=lambda x: isinstance(x, P))
    params = jax.device_put(params, p_sh)
    opt = jax.device_put(opt, o_sh)
    batch = {
        "tokens": jnp.tile(jnp.arange(16, dtype=jnp.int32)[None], (4, 1)) % cfg.vocab_size,
        "labels": (jnp.tile(jnp.arange(16, dtype=jnp.int32)[None], (4, 1)) + 1) % cfg.vocab_size,
    }
    b_sh = {k: NamedSharding(mesh, P("data", None)) for k in batch}
    batch = jax.device_put(batch, b_sh)
    step = jax.jit(make_train_step(cfg, tx, grad_shardings=p_sh),
                   in_shardings=(p_sh, o_sh, b_sh), out_shardings=(p_sh, o_sh, None))
    new_params, new_opt, metrics = step(params, opt, batch)
    sharded_loss = float(metrics["loss"])

# single-device reference
params1, meta1 = cfg.init(jax.random.PRNGKey(0))
tx1 = slim_adam(1e-3, rules_as_tree(table3_rules(meta1), params1, meta1))
step1 = jax.jit(make_train_step(cfg, tx1))
new_params1, _, metrics1 = step1(params1, tx1.init(params1), jax.device_get(batch))
ref_loss = float(metrics1["loss"])

max_err = max(
    float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
    for a, b in zip(jax.tree.leaves(jax.device_get(new_params)), jax.tree.leaves(new_params1))
)
print(json.dumps({"sharded_loss": sharded_loss, "ref_loss": ref_loss, "max_err": max_err}))
"""


@pytest.mark.slow
def test_spmd_step_matches_single_device(tmp_path):
    """8-device SPMD SlimAdam step == single-device step (numerics + specs)."""
    script = tmp_path / "spmd_check.py"
    script.write_text(SPMD_SCRIPT)
    src = str(Path(__file__).resolve().parents[1] / "src")
    proc = subprocess.run([sys.executable, str(script)], capture_output=True, text=True,
                          env={**__import__("os").environ, "PYTHONPATH": src}, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert abs(out["sharded_loss"] - out["ref_loss"]) < 1e-3, out
    assert out["max_err"] < 5e-3, out


PIPE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import jax, jax.numpy as jnp
from repro.sharding.pipeline import gpipe, sequential_reference

mesh = jax.make_mesh((4,), ("pipe",))
P_stages, M, mb, d = 4, 8, 2, 16
key = jax.random.PRNGKey(0)
stage_params = {"w": jax.random.normal(key, (P_stages, d, d)) * 0.3,
                "b": jax.random.normal(key, (P_stages, d)) * 0.1}
x = jax.random.normal(jax.random.PRNGKey(1), (M, mb, d))

def stage_fn(p, x):
    return jnp.tanh(x @ p["w"] + p["b"])

out = jax.jit(lambda sp, x: gpipe(stage_fn, sp, x, mesh=mesh))(stage_params, x)
ref = sequential_reference(stage_fn, stage_params, x)
err = float(jnp.max(jnp.abs(out - ref)))
print(json.dumps({"err": err}))
"""


@pytest.mark.slow
def test_gpipe_matches_sequential(tmp_path):
    """4-stage GPipe pipeline over a 'pipe' mesh axis == sequential stages."""
    script = tmp_path / "pipe_check.py"
    script.write_text(PIPE_SCRIPT)
    src = str(Path(__file__).resolve().parents[1] / "src")
    proc = subprocess.run([sys.executable, str(script)], capture_output=True, text=True,
                          env={**__import__("os").environ, "PYTHONPATH": src}, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["err"] < 1e-5, out
