"""Unit + property tests for the paper core: SNR analysis and rule derivation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or deterministic fallback

from repro.core import (
    ParamMeta,
    SNRTracker,
    derive_rules,
    rules_as_tree,
    second_moment_savings,
    snr_along_dims,
    table3_rules,
)

META_2D = ParamMeta(axes=("embed", "mlp"), role="mlp_up", fan_in=("embed",), fan_out=("mlp",))


class TestSNRDefinition:
    def test_constant_rows_infinite_snr(self):
        """Entries constant along K -> zero variance -> enormous SNR."""
        v = jnp.broadcast_to(jnp.arange(1.0, 5.0)[:, None], (4, 8))
        s = snr_along_dims(v, (1,))
        assert float(s) > 1e10

    def test_known_value(self):
        """SNR of iid U(0,1)-ish values: mean^2/var computable by hand."""
        v = jnp.array([[1.0, 3.0]] * 5)  # mean 2, var 1 along axis 1
        s = snr_along_dims(v, (1,))
        np.testing.assert_allclose(float(s), 4.0, rtol=1e-5)

    def test_scalar_output_over_remaining_dims(self):
        v = jnp.arange(24.0).reshape(2, 3, 4)
        s = snr_along_dims(v, (2,))
        assert s.shape == ()

    def test_per_remaining_dim(self):
        v = jnp.arange(24.0).reshape(2, 3, 4) + 1.0
        s = snr_along_dims(v, (2,), per_remaining_dim=0)
        assert s.shape == (2,)

    @settings(max_examples=30, deadline=None)
    @given(st.floats(min_value=0.1, max_value=100.0))
    def test_scale_invariance(self, c):
        """SNR_K(cV) == SNR_K(V): ratios of second moments cancel scale."""
        rng = np.random.default_rng(0)
        v = jnp.asarray(rng.uniform(0.5, 2.0, (6, 10)).astype(np.float32))
        s1 = float(snr_along_dims(v, (1,)))
        s2 = float(snr_along_dims(c * v, (1,)))
        assert np.isclose(s1, s2, rtol=1e-3)

    @settings(max_examples=20, deadline=None)
    @given(st.integers(min_value=2, max_value=16), st.integers(min_value=2, max_value=16))
    def test_snr_nonnegative(self, r, c):
        rng = np.random.default_rng(r * 100 + c)
        v = jnp.asarray(np.abs(rng.normal(size=(r, c))).astype(np.float32))
        assert float(snr_along_dims(v, (0,))) >= 0.0
        assert float(snr_along_dims(v, (1,))) >= 0.0

    def test_tighter_concentration_higher_snr(self):
        """Lower relative variance along K must give higher SNR_K."""
        rng = np.random.default_rng(1)
        base = rng.uniform(1.0, 2.0, (8, 32)).astype(np.float32)
        tight = 1.0 + 0.01 * (base - base.mean())
        assert float(snr_along_dims(jnp.asarray(tight), (1,))) > float(
            snr_along_dims(jnp.asarray(base), (1,)))


class TestMeta:
    def test_candidate_ks(self):
        ks = META_2D.candidate_ks()
        assert set(ks) == {"fan_in", "fan_out", "both"}
        assert ks["both"] == ("embed", "mlp")

    def test_vector_like_no_candidates(self):
        m = ParamMeta(axes=("embed",), role="norm")
        assert m.is_vector_like and m.candidate_ks() == {}

    def test_structural_axes_excluded(self):
        m = ParamMeta(axes=("layers", "embed", "mlp"), role="mlp_up",
                      fan_in=("embed",), fan_out=("mlp",))
        assert not m.is_vector_like
        assert m.dims_of(("embed",)) == (1,)
        with pytest.raises(ValueError):
            ParamMeta(axes=("layers", "embed"), role="mlp_up", fan_in=("layers",))


class TestRules:
    def _setup(self):
        params = {"w": jnp.ones((8, 16)), "n": jnp.ones((8,))}
        meta = {"w": META_2D, "n": ParamMeta(axes=("embed",), role="norm")}
        return params, meta

    def test_derive_picks_argmax_above_cutoff(self):
        params, meta = self._setup()
        avg = {"w": {"fan_in": 5.0, "fan_out": 2.0, "both": 1.0}, "n": {}}
        rules = derive_rules(avg, meta, cutoff=1.0)
        assert rules["w"] == ("embed",)
        assert rules["n"] is None

    def test_derive_below_cutoff_uncompressed(self):
        params, meta = self._setup()
        avg = {"w": {"fan_in": 0.5, "fan_out": 0.3, "both": 0.2}, "n": {}}
        assert derive_rules(avg, meta, cutoff=1.0)["w"] is None

    def test_cutoff_monotonicity(self):
        """Raising the cutoff can only reduce the set of compressed tensors."""
        params, meta = self._setup()
        avg = {"w": {"fan_in": 1.5, "fan_out": 0.7, "both": 0.4}, "n": {}}
        compressed = [derive_rules(avg, meta, cutoff=c)["w"] is not None
                      for c in (0.5, 1.0, 1.4, 1.6, 3.0)]
        assert compressed == sorted(compressed, reverse=True)

    def test_savings_accounting(self):
        params, meta = self._setup()
        rules = {"w": ("mlp",), "n": None}
        s = second_moment_savings(params, meta, rules)
        # w stores 8 of 128 entries; n stores 8 of 8
        assert s["stored_second_moments"] == 16.0
        np.testing.assert_allclose(s["saved_fraction"], 1 - 16 / 136)

    def test_table3_roles(self):
        from repro.configs import get_reduced
        cfg = get_reduced("smollm_135m")
        params, meta = cfg.init(jax.random.PRNGKey(0))
        rules = table3_rules(meta)
        named = {k: v for k, v in rules.items()}
        # attention q/k compress fan_in (embed), v/o fan_out/None per table
        for name, rule in named.items():
            if ".wq" in name or ".wk" in name:
                assert rule == ("embed",), name
            if "mixer_norm" in name or "ffn_norm" in name:
                assert rule is None, name
        # embedding compresses the embedding dim, never vocab
        assert named["embed"] == ("embed",)

    def test_rules_as_tree_positions(self):
        params, meta = self._setup()
        tree = rules_as_tree({"w": ("mlp",), "n": None}, params, meta)
        assert tree == {"w": (1,), "n": ()}


class TestTracker:
    def test_time_average(self):
        tr = SNRTracker()
        tr.update({"w": {"fan_in": jnp.asarray(2.0)}}, step=100)
        tr.update({"w": {"fan_in": jnp.asarray(4.0)}}, step=200)
        assert tr.averaged()["w"]["fan_in"] == 3.0

    def test_measure_cadence(self):
        """Paper: every 100 steps until 1000, then every 1000."""
        steps = [s for s in range(1, 5001) if SNRTracker.should_measure(s)]
        assert steps[:10] == [100, 200, 300, 400, 500, 600, 700, 800, 900, 1000]
        assert steps[10:] == [2000, 3000, 4000, 5000]
