"""Fault-tolerance substrate: in-pass health, guarded step, guard policy,
fault injection, graceful kernel degradation."""
import subprocess
import sys
import textwrap
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core.slim_adam import scale_by_slim_adam, slim_adam
from repro.data import DataConfig, ZipfLM
from repro.kernels.fused_adam import adam_precond, health_terms
from repro.kernels.slim_update import (slim_partial_stats_batched,
                                       slim_precond_batched)
from repro.optim import fused
from repro.optim.adam import scale_by_adam
from repro.train import (FaultPlan, Guard, GuardConfig, Trainer,
                         TrainerConfig, inject_kernel_failure)
from repro.train.guard import BACKOFF, OK, ROLLBACK, SKIP
from repro.train.step import make_train_step
from repro.train.trainer import slim_rule_dims


def _poisoned(key, shape, n_nan=2, n_inf=1):
    g = jax.random.normal(key, shape, jnp.float32)
    flat = g.ravel()
    flat = flat.at[:n_nan].set(jnp.nan).at[n_nan:n_nan + n_inf].set(jnp.inf)
    return flat.reshape(shape)


class TestKernelHealth:
    """The with_health kernel outputs vs the jnp oracle (health_terms)."""

    def test_adam_precond_health_counts_and_sumsq(self):
        g = _poisoned(jax.random.PRNGKey(0), (48, 96), n_nan=3, n_inf=2)
        m = jnp.zeros_like(g)
        v = jnp.zeros_like(g)
        u, m2, v2, h = adam_precond(g, m, v, with_health=True, interpret=True)
        ref = health_terms(g)
        assert float(h[0]) == 5.0
        np.testing.assert_allclose(np.asarray(h), np.asarray(ref), rtol=1e-6)
        # the 3 tensor outputs are identical with and without health
        u0, m0, v0 = adam_precond(g, m, v, interpret=True)
        np.testing.assert_array_equal(np.asarray(u), np.asarray(u0))
        np.testing.assert_array_equal(np.asarray(m2), np.asarray(m0))
        np.testing.assert_array_equal(np.asarray(v2), np.asarray(v0))

    def test_adam_precond_health_padded_shapes(self):
        """Pad-and-recurse must pass the accumulator through untrimmed —
        zero padding contributes nothing to either health term."""
        g = _poisoned(jax.random.PRNGKey(1), (37, 101), n_nan=1, n_inf=1)
        z = jnp.zeros_like(g)
        *_, h = adam_precond(g, z, z, with_health=True, interpret=True)
        np.testing.assert_allclose(np.asarray(h), np.asarray(health_terms(g)),
                                   rtol=1e-6)

    @pytest.mark.parametrize("axis", [0, 1])
    def test_slim_kernels_health_both_axes(self, axis):
        g = _poisoned(jax.random.PRNGKey(2), (2, 16, 64), n_nan=2, n_inf=0)
        m = jnp.zeros_like(g)
        red_shape = (2, 1, 64) if axis == 0 else (2, 16, 1)
        v = jnp.zeros((2,) + red_shape[1:], jnp.float32)
        ref = health_terms(g)
        outs = slim_precond_batched(g, m, v, axis=axis, with_health=True,
                                    interpret=True)
        np.testing.assert_allclose(np.asarray(outs[-1]), np.asarray(ref), rtol=1e-6)
        outs = slim_partial_stats_batched(g, m, axis=axis, with_health=True,
                                          interpret=True)
        np.testing.assert_allclose(np.asarray(outs[-1]), np.asarray(ref), rtol=1e-6)

    def test_health_with_snr_combined(self):
        """health is always the LAST output, after any snr stats."""
        g = jax.random.normal(jax.random.PRNGKey(3), (2, 16, 64))
        m = jnp.zeros_like(g)
        v = jnp.zeros((2, 16, 1), jnp.float32)
        base = slim_precond_batched(g, m, v, axis=1, interpret=True)
        both = slim_precond_batched(g, m, v, axis=1, with_snr=True,
                                    with_health=True, interpret=True)
        assert len(both) == len(base) + 3   # 2 snr stats + 1 health
        assert both[-1].shape == (2,)
        np.testing.assert_allclose(np.asarray(both[-1]),
                                   np.asarray(health_terms(g)), rtol=1e-6)


class TestStepHealthState:
    """emit_health on the transformations: StepHealth on state, jnp/fused
    parity, and None-field layout stability."""

    def _grads_params(self):
        params = {"w": jnp.ones((8, 16)), "b": jnp.ones((16,))}
        grads = {"w": jnp.ones((8, 16)).at[0, 0].set(jnp.nan),
                 "b": jnp.ones((16,))}
        return params, grads

    @pytest.mark.parametrize("backend", ["jnp", "fused"])
    def test_scale_by_adam_health(self, backend):
        params, grads = self._grads_params()
        tx = scale_by_adam(backend=backend, emit_health=True)
        _, st = jax.jit(tx.update)(grads, tx.init(params))
        h = st.health
        assert isinstance(h, fused.StepHealth)
        # leaf order is the flatten order: "b" (clean) before "w" (poisoned)
        np.testing.assert_array_equal(np.asarray(h.nonfinite), [0.0, 1.0])
        assert bool(h.bad)
        # finite-masked norm: sqrt(sum of the finite squares)
        expect = np.sqrt(8 * 16 - 1 + 16)
        np.testing.assert_allclose(float(h.grad_norm), expect, rtol=1e-6)

    @pytest.mark.parametrize("backend", ["jnp", "fused"])
    def test_scale_by_slim_adam_health(self, backend):
        params, grads = self._grads_params()
        dims = {"w": (1,), "b": ()}
        tx = scale_by_slim_adam(dims, backend=backend, emit_health=True)
        _, st = jax.jit(tx.update)(grads, tx.init(params))
        np.testing.assert_array_equal(np.asarray(st.health.nonfinite), [0.0, 1.0])
        assert bool(st.health.bad)

    def test_plain_state_has_no_health_leaves(self):
        """health=None must contribute no pytree leaves: checkpoints and jit
        signatures of non-guarded states are byte-identical to before."""
        params, _ = self._grads_params()
        st = scale_by_adam().init(params)
        assert st.health is None
        assert len(jax.tree_util.tree_leaves(st)) == 5  # count + 2mu + 2nu

    def test_clean_grads_not_bad(self):
        params, _ = self._grads_params()
        grads = jax.tree.map(jnp.ones_like, params)
        tx = scale_by_adam(backend="fused", emit_health=True)
        _, st = tx.update(grads, tx.init(params))
        assert not bool(st.health.bad)
        assert float(jnp.sum(st.health.nonfinite)) == 0.0


class TestGuardedStep:
    def _setup(self, emit_health=True):
        cfg = get_reduced("smollm_135m")
        params, meta = cfg.init(jax.random.PRNGKey(0))
        dims = slim_rule_dims("slim", params, meta)
        tx = slim_adam(1e-3, dims, backend="fused", emit_health=emit_health)
        data = ZipfLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                                 global_batch=4))
        batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
        step = jax.jit(make_train_step(cfg, tx, guard=True))
        return step, params, tx.init(params), batch

    @staticmethod
    def _ctl(lr=1.0, gs=1.0):
        return {"lr_scale": jnp.asarray(lr, jnp.float32),
                "grad_scale": jnp.asarray(gs, jnp.float32)}

    def test_nan_step_skipped_bit_identical(self):
        """A poisoned step must leave params, moments, and count exactly
        (bit-for-bit) at their pre-step values."""
        step, params, opt_state, batch = self._setup()
        p1, s1, m1 = step(params, opt_state, batch, self._ctl())
        assert float(m1["step_skipped"]) == 0.0
        p2, s2, m2 = step(p1, s1, batch, self._ctl(gs=float("nan")))
        assert float(m2["step_skipped"]) == 1.0
        assert float(m2["nonfinite_count"]) > 0
        for a, b in zip(jax.tree_util.tree_leaves(p1),
                        jax.tree_util.tree_leaves(p2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree_util.tree_leaves(s1),
                        jax.tree_util.tree_leaves(s2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # and training continues cleanly afterwards
        _, _, m3 = step(p2, s2, batch, self._ctl())
        assert float(m3["step_skipped"]) == 0.0

    def test_lr_scale_scales_update(self):
        step, params, opt_state, batch = self._setup()
        p_full, _, _ = step(params, opt_state, batch, self._ctl(lr=1.0))
        p_half, _, _ = step(params, opt_state, batch, self._ctl(lr=0.5))
        d = lambda a, b: np.sqrt(sum(
            float(jnp.sum((x - y) ** 2)) for x, y in
            zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b))))
        np.testing.assert_allclose(d(p_half, params) / d(p_full, params),
                                   0.5, rtol=1e-4)

    def test_grad_norm_fallback_without_emit_health(self):
        """Optimizers without in-pass health still get guarded via the
        finiteness of the global grad norm."""
        step, params, opt_state, batch = self._setup(emit_health=False)
        _, _, m = step(params, opt_state, batch, self._ctl(gs=float("nan")))
        assert float(m["step_skipped"]) == 1.0


class TestGuardPolicy:
    def test_spike_backoff_and_recovery(self):
        g = Guard(GuardConfig(min_history=4, spike_z=4.0, lr_backoff=0.5,
                              lr_recover=2.0))
        for i in range(8):
            assert g.observe(1.0 + 0.01 * (i % 3)) == OK
        assert g.observe(100.0) == BACKOFF
        assert g.lr_scale == 0.5
        assert g.counters["spikes"] == 1
        assert g.observe(1.0) == OK              # good step recovers lr
        assert g.lr_scale == 1.0                  # capped at 1
        # the spike never entered the window: the next normal loss is OK
        assert g.observe(1.01) == OK

    def test_no_spike_verdict_before_min_history(self):
        g = Guard(GuardConfig(min_history=8))
        assert g.observe(1.0) == OK
        assert g.observe(1000.0) == OK           # too little history

    def test_skip_escalates_to_rollback(self):
        g = Guard(GuardConfig(max_bad_steps=3, max_rollbacks=2))
        assert g.observe(float("nan"), skipped=True, nonfinite=10) == SKIP
        assert g.observe(float("nan"), skipped=True, nonfinite=10) == SKIP
        assert g.observe(float("nan"), skipped=True, nonfinite=10) == ROLLBACK
        assert g.counters["skipped"] == 3
        assert g.counters["nonfinite_total"] == 30
        g.note_rollback()
        assert g.consecutive_bad == 0

    def test_rollbacks_capped(self):
        g = Guard(GuardConfig(max_bad_steps=1, max_rollbacks=1))
        assert g.observe(0.0, skipped=True) == ROLLBACK
        g.note_rollback()
        # past the rollback budget the guard degrades to plain skips
        assert g.observe(0.0, skipped=True) == SKIP

    def test_nonfinite_loss_is_spike(self):
        g = Guard(GuardConfig(min_history=2, max_bad_steps=99))
        g.observe(1.0), g.observe(1.0)
        assert g.observe(float("inf")) == BACKOFF

    def test_stats_keys(self):
        s = Guard().stats()
        for k in ("guard_skipped", "guard_spikes", "guard_backoffs",
                  "guard_rollbacks", "guard_nonfinite_total", "guard_lr_scale"):
            assert k in s


class TestFaultPlan:
    def test_deterministic_schedule(self):
        fp = FaultPlan(nan_grad_steps=(3,), inf_grad_steps=(5,),
                       spike_steps=(7,), spike_scale=10.0)
        assert np.isnan(fp.grad_scale(3))
        assert np.isinf(fp.grad_scale(5))
        assert fp.grad_scale(4) == 1.0
        assert fp.corrupt_loss(7, 2.0) == 20.0
        assert fp.corrupt_loss(8, 2.0) == 2.0
        assert fp.fault_steps == (3, 5, 7)


class TestKernelDegradation:
    def test_degraded_leaves_counted_and_jnp_parity(self):
        """An injected pallas failure must degrade per-leaf to the jnp
        reference path — same numbers, counted, one warning."""
        gs = [jax.random.normal(jax.random.PRNGKey(i), (32, 64)) for i in range(2)]
        ms = [jnp.zeros_like(g) for g in gs]
        vs = [jnp.zeros_like(g) for g in gs]
        ref = fused.adam_tree_update(gs, list(ms), list(vs), b1=0.9, b2=0.99,
                                     eps=1e-8, count=1)
        with inject_kernel_failure():
            with pytest.warns(UserWarning, match="degrading leaf"):
                out = fused.adam_tree_update(gs, list(ms), list(vs), b1=0.9,
                                             b2=0.99, eps=1e-8, count=1)
            assert fused.kernel_degraded_leaves() >= 1
        fused.reset_kernel_degradation()
        for a, b in zip(jax.tree_util.tree_leaves(ref),
                        jax.tree_util.tree_leaves(out)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)

    def test_regime_counts_has_degraded_key(self):
        from repro.sharding.shardspec import regime_counts
        counts = regime_counts([], degraded=2)
        assert counts["degraded"] == 2
        assert set(counts) == {"local", "psum", "psum_jnp", "jnp", "degraded"}


@pytest.mark.slow
class TestTrainerGuardE2E:
    def test_injected_run_completes_with_counters(self, tmp_path):
        cfg = get_reduced("smollm_135m")
        data = ZipfLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                                 global_batch=4))
        tc = TrainerConfig(total_steps=24, log_every=6, ckpt_every=4,
                           ckpt_dir=str(tmp_path), backend="fused",
                           guard=GuardConfig(max_bad_steps=2, min_history=4))
        faults = FaultPlan(nan_grad_steps=(5,), spike_steps=(13, 14),
                           spike_scale=100.0)
        tr = Trainer(cfg, "slim", 1e-3, data, tc, faults=faults)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            last = tr.run()
        assert tr.step == 24
        assert tr.guard.counters["skipped"] >= 1
        assert tr.guard.counters["spikes"] >= 1
        assert tr.guard.counters["rollbacks"] >= 1
        assert np.isfinite(last["loss"])
        assert "guard_rollbacks" in last

    def test_unguarded_trainer_unchanged(self):
        """guard=None keeps the plain 3-arg step and no guard attributes in
        metrics — the default path is untouched."""
        cfg = get_reduced("smollm_135m")
        data = ZipfLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                                 global_batch=4))
        tr = Trainer(cfg, "adam", 1e-3, data,
                     TrainerConfig(total_steps=3, log_every=3))
        last = tr.run()
        assert tr.guard is None
        assert "guard_skipped" not in last
        assert "step_skipped" not in last


_MULTIDEV_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=8")
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.optim import fused

    assert jax.device_count() == 8
    mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
    shapes = [(8, 64), (16, 32), (64,), (4, 8, 16)]
    dims = [(1,), (0,), (), (2,)]
    specs = [P(None, "model"), P("model", None), P(None), P(None, None, "model")]
    gs = []
    for i, s in enumerate(shapes):
        g = jax.random.normal(jax.random.PRNGKey(i), s, jnp.float32)
        flat = g.ravel().at[:i].set(jnp.nan)   # leaf i gets i nonfinite entries
        gs.append(flat.reshape(s))
    ms = [jnp.zeros_like(g) for g in gs]
    vs = [jnp.zeros(tuple(1 if j in set(d) else n for j, n in enumerate(s)),
                    jnp.float32) for s, d in zip(shapes, dims)]
    u, m, v, h = fused.slim_tree_update(
        gs, ms, vs, dims, b1=0.9, b2=0.95, eps=1e-8, count=1,
        mesh=mesh, spec_leaves=specs, with_health=True)
    # psum-leaf nonfinite flags vs the jnp oracle: exact per-leaf counts
    np.testing.assert_array_equal(np.asarray(h.nonfinite), [0., 1., 2., 3.])
    ss_ref = sum(float(jnp.sum(jnp.where(jnp.isfinite(g), g * g, 0.0)))
                 for g in gs)
    np.testing.assert_allclose(float(h.grad_sumsq), ss_ref, rtol=1e-5)
    u2, m2, v2, h2 = fused.slim_tree_update(
        [jnp.nan_to_num(g, nan=0.0) for g in gs], ms, vs, dims,
        b1=0.9, b2=0.95, eps=1e-8, count=1,
        mesh=mesh, spec_leaves=specs, with_health=True)
    assert float(jnp.sum(h2.nonfinite)) == 0.0
    print("MULTIDEV_HEALTH_OK")
""")


@pytest.mark.slow
class TestShardedHealth:
    def test_8device_psum_health_parity(self):
        """Sharded health under shard_map on 8 host devices: per-leaf
        nonfinite counts and the global sumsq must be exact (replication
        de-duplicated before the psum) vs the jnp oracle."""
        r = subprocess.run([sys.executable, "-c", _MULTIDEV_SCRIPT],
                           capture_output=True, text=True, timeout=600)
        assert r.returncode == 0, r.stderr[-3000:]
        assert "MULTIDEV_HEALTH_OK" in r.stdout
