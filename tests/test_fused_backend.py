"""Backend parity: backend='fused' must match backend='jnp' to fp32 tolerance
for dense Adam and for every compression-spec shape, plus bucketing
round-trip and the full GPT-small param tree."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.slim_adam import scale_by_slim_adam, slim_adam
from repro.kernels import canon2d, canon_apply, canon_restore, slim_update_nd
from repro.kernels.ref import slim_update_ref
from repro.optim import adamw, apply_updates, resolve_backend, scale_by_adam

TOL = dict(rtol=1e-5, atol=1e-6)


def _tree_allclose(a, b, **tol):
    tol = tol or TOL
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x, np.float32), np.asarray(y, np.float32), **tol)


def _grads(params, i):
    k = jax.random.PRNGKey(i)
    return jax.tree.map(lambda x: jax.random.normal(k, x.shape).astype(x.dtype) * 0.1, params)


def _mixed_params():
    """Every canonicalization case: 1-D, 2-D, non-tile-multiple (padding
    path), >2-D, scalar (jnp fallback), bf16."""
    key = jax.random.PRNGKey(0)
    return {
        "w": jax.random.normal(key, (12, 8)),
        "odd": jax.random.normal(key, (257, 129)),   # exercises kernel padding
        "vec": jnp.linspace(-1.0, 1.0, 37),
        "scalar": jnp.asarray(0.5),
        "conv": jax.random.normal(key, (3, 3, 8, 16)),
        "bf16": jax.random.normal(key, (33, 65)).astype(jnp.bfloat16),
    }


class TestDenseAdamParity:
    @pytest.mark.parametrize("bucket_min_size", [0, 1 << 20])
    def test_multi_step_trajectory(self, bucket_min_size):
        params = _mixed_params()
        tx_j = scale_by_adam(0.9, 0.95, 1e-8)
        tx_f = scale_by_adam(0.9, 0.95, 1e-8, backend="fused",
                             bucket_min_size=bucket_min_size)
        sj, sf = tx_j.init(params), tx_f.init(params)
        for i in range(3):
            g = _grads(params, i)
            uj, sj = jax.jit(tx_j.update)(g, sj)
            uf, sf = jax.jit(tx_f.update)(g, sf)
        _tree_allclose(uj, uf)
        _tree_allclose(sj.mu, sf.mu)
        _tree_allclose(sj.nu, sf.nu)

    def test_state_layout_backend_independent(self):
        params = _mixed_params()
        sj = scale_by_adam().init(params)
        sf = scale_by_adam(backend="fused").init(params)
        assert jax.tree_util.tree_structure(sj) == jax.tree_util.tree_structure(sf)

    def test_adamw_end_to_end(self):
        params = {"w": jax.random.normal(jax.random.PRNGKey(1), (31, 17)),
                  "n": jnp.ones((31,))}
        p1 = p2 = params
        tx1 = adamw(1e-3, weight_decay=0.1)
        tx2 = adamw(1e-3, weight_decay=0.1, backend="fused")
        s1, s2 = tx1.init(p1), tx2.init(p2)
        for i in range(3):
            u1, s1 = tx1.update(_grads(p1, i), s1, p1)
            u2, s2 = tx2.update(_grads(p2, i), s2, p2)
            p1, p2 = apply_updates(p1, u1), apply_updates(p2, u2)
        _tree_allclose(p1, p2)


class TestSlimParity:
    # Every compression-spec shape: fan_in / fan_out on 2-D, 1-D leaf,
    # multi-dim K on 4-D, full reduction (AdaLayer), and non-tile multiples.
    SPECS = [
        ((12, 8), (1,)),       # fan_in (minor kernel — no transpose)
        ((12, 8), (0,)),       # fan_out (major/sublane kernel — no transpose)
        ((257, 129), (1,)),    # padding path
        ((257, 129), (0,)),
        ((37,), (0,)),         # 1-D leaf, fully reduced
        ((3, 3, 8, 16), (0, 1, 2)),  # conv fan_in (leading multi-dim K, major)
        ((4, 6, 10), (0, 2)),  # interleaved multi-dim K (transpose fallback)
        ((12, 8), (0, 1)),     # AdaLayer: everything reduced
        ((3, 24, 2, 8), (1,)),  # scan-stacked middle K (batched major kernel)
    ]

    @pytest.mark.parametrize("shape,dims", SPECS)
    def test_leaf_spec_parity(self, shape, dims):
        params = {"w": jax.random.normal(jax.random.PRNGKey(2), shape)}
        tx_j = scale_by_slim_adam({"w": dims})
        tx_f = scale_by_slim_adam({"w": dims}, backend="fused")
        sj, sf = tx_j.init(params), tx_f.init(params)
        assert jax.tree.leaves(sj.nu)[0].shape == jax.tree.leaves(sf.nu)[0].shape
        for i in range(2):
            g = _grads(params, i)
            uj, sj = jax.jit(tx_j.update)(g, sj)
            uf, sf = jax.jit(tx_f.update)(g, sf)
        _tree_allclose(uj, uf)
        _tree_allclose(sj.nu, sf.nu)

    def test_mixed_tree_with_fallbacks(self):
        params = _mixed_params()
        dims = {"w": (1,), "odd": (0,), "vec": (0,), "scalar": (),
                "conv": (0, 1, 2), "bf16": (1,)}
        tx_j = scale_by_slim_adam(dims)
        tx_f = scale_by_slim_adam(dims, backend="fused")
        sj, sf = tx_j.init(params), tx_f.init(params)
        for i in range(3):
            g = _grads(params, i)
            uj, sj = jax.jit(tx_j.update)(g, sj)
            uf, sf = jax.jit(tx_f.update)(g, sf)
        _tree_allclose(uj, uf, rtol=1e-5, atol=2e-5)  # bf16 grads
        _tree_allclose(sj.nu, sf.nu)

    def test_no_first_moment(self):
        params = {"w": jax.random.normal(jax.random.PRNGKey(3), (24, 16))}
        tx_j = scale_by_slim_adam({"w": (1,)}, use_first_moment=False)
        tx_f = scale_by_slim_adam({"w": (1,)}, use_first_moment=False, backend="fused")
        sj, sf = tx_j.init(params), tx_f.init(params)
        g = _grads(params, 0)
        uj, sj = tx_j.update(g, sj)
        uf, sf = tx_f.update(g, sf)
        assert sf.mu is None
        _tree_allclose(uj, uf)

    def test_slim_adam_recipe_end_to_end(self):
        params = {"w": jax.random.normal(jax.random.PRNGKey(4), (40, 24)),
                  "n": jnp.ones((24,))}
        dims = {"w": (1,), "n": ()}
        p1 = p2 = params
        tx1 = slim_adam(1e-3, dims, weight_decay=0.1)
        tx2 = slim_adam(1e-3, dims, weight_decay=0.1, backend="fused")
        s1, s2 = tx1.init(p1), tx2.init(p2)
        for i in range(3):
            u1, s1 = tx1.update(_grads(p1, i), s1, p1)
            u2, s2 = tx2.update(_grads(p2, i), s2, p2)
            p1, p2 = apply_updates(p1, u1), apply_updates(p2, u2)
        _tree_allclose(p1, p2)


class TestBucketing:
    # megakernel=False throughout: bucketing lives on the per-leaf dispatch
    # path the megaplan supersedes (the default grouped path never buckets).
    def test_roundtrip_preserves_leaf_identity(self):
        """Scatter-back: every bucketed leaf keeps its shape, dtype and its
        own values (no cross-leaf bleed at segment boundaries)."""
        key = jax.random.PRNGKey(5)
        params = {f"p{i}": jax.random.normal(jax.random.fold_in(key, i), (5, 3 + i))
                  for i in range(6)}
        tx_b = scale_by_adam(backend="fused", bucket_min_size=1 << 20,
                             megakernel=False)  # bucket all
        tx_p = scale_by_adam(backend="fused", bucket_min_size=0,
                             megakernel=False)  # none
        sb, sp = tx_b.init(params), tx_p.init(params)
        g = _grads(params, 0)
        ub, sb = jax.jit(tx_b.update)(g, sb)
        up, sp = jax.jit(tx_p.update)(g, sp)
        for k in params:
            assert ub[k].shape == params[k].shape
            assert ub[k].dtype == jnp.float32
        _tree_allclose(ub, up)
        _tree_allclose(sb.nu, sp.nu)

    def test_single_small_leaf_skips_bucket(self):
        params = {"only": jnp.ones((4, 4))}
        tx = scale_by_adam(backend="fused", megakernel=False)
        s = tx.init(params)
        u, s = tx.update(_grads(params, 0), s)
        assert u["only"].shape == (4, 4)


class TestCanonicalization:
    @pytest.mark.parametrize("shape,dims", [
        ((6, 4), (1,)), ((6, 4), (0,)), ((2, 3, 4), (1,)), ((2, 3, 4), (0, 2)),
        ((5,), (0,)), ((2, 3, 4, 5), (0, 1, 2, 3)),
    ])
    def test_canon_roundtrip(self, shape, dims):
        x = jnp.arange(np.prod(shape), dtype=jnp.float32).reshape(shape)
        cn = canon2d(shape, dims)
        x2 = canon_apply(x, cn)
        assert x2.shape == cn.view
        np.testing.assert_array_equal(canon_restore(x2, cn, shape), x)
        # the canonical mean over the planned reduction axis equals the jnp mean
        np.testing.assert_allclose(
            jnp.mean(x2, axis=cn.red_axis).ravel(), jnp.mean(x, axis=dims).ravel(),
            rtol=1e-6)

    def test_out_of_range_dims_rejected(self):
        """Parity with the jnp path's error behavior — no silent d % ndim wrap."""
        with pytest.raises(ValueError, match="out of range"):
            canon2d((4, 8), (2,))
        assert canon2d((4, 8), (-1,)).perm == (0, 1)  # negative dims still ok

    def test_slim_update_nd_matches_oracle(self):
        shape, dims = (4, 6, 10), (0, 2)
        k = jax.random.split(jax.random.PRNGKey(6), 3)
        p = jax.random.normal(k[0], shape)
        g = jax.random.normal(k[1], shape) * 0.1
        m = jax.random.normal(k[2], shape) * 0.01
        v = jnp.abs(p).mean(axis=dims, keepdims=True) * 1e-3
        kw = dict(lr=1e-3, b1=0.9, b2=0.95, eps=1e-8, wd=0.1, count=3)
        po, mo, vo = slim_update_nd(p, g, m, v, dims=dims, **kw)
        cn = canon2d(shape, dims)
        pr, mr, vr = slim_update_ref(canon_apply(p, cn), canon_apply(g, cn),
                                     canon_apply(m, cn),
                                     canon_apply(v, cn, reduced_cols=True), **kw)
        np.testing.assert_allclose(po, canon_restore(pr, cn, shape), **TOL)
        np.testing.assert_allclose(mo, canon_restore(mr, cn, shape), **TOL)
        np.testing.assert_allclose(vo, canon_restore(vr, cn, v.shape), **TOL)


class TestSNRFusedParity:
    @pytest.mark.parametrize("shape,dims", [
        ((37, 130), (1,)), ((37, 130), (0,)), ((5, 8, 12), (0, 2)), ((5, 8, 12), (2,)),
    ])
    def test_snr_backend_parity(self, shape, dims):
        from repro.core.snr import snr_along_dims
        v = jnp.abs(jax.random.normal(jax.random.PRNGKey(7), shape)) + 0.1
        a = float(snr_along_dims(v, dims))
        b = float(snr_along_dims(v, dims, backend="fused"))
        np.testing.assert_allclose(a, b, rtol=1e-4)

    def test_high_snr_near_constant_rows(self):
        """The regime SNR analysis exists for: variance orders of magnitude
        below mean^2. A naive one-pass E[v^2]-E[v]^2 cancels catastrophically
        here; the centered kernel must track the two-pass jnp value."""
        from repro.core.snr import snr_along_dims
        noise = jax.random.normal(jax.random.PRNGKey(8), (16, 256)) * 1e-5
        v = 1.0 + noise  # mean ~1, var ~1e-10 -> SNR ~1e10
        a = float(snr_along_dims(v, (1,)))
        b = float(snr_along_dims(v, (1,), backend="fused"))
        assert a > 1e8
        np.testing.assert_allclose(a, b, rtol=1e-2)


class TestGPTSmallTreeParity:
    @pytest.mark.slow
    def test_full_tree_fused_matches_jnp(self):
        """Acceptance: fused == jnp within 1e-5 over the GPT-small param tree
        (reduced depth/width — same leaf set, roles and compression specs as
        the paper config; interpret-mode kernels make the full 124M-param
        tree impractical in CI)."""
        from repro.configs import gpt_small
        from repro.core import rules_as_tree, table3_rules

        cfg = gpt_small.reduced()
        params, meta = cfg.init(jax.random.PRNGKey(0))
        dims = rules_as_tree(table3_rules(meta), params, meta)
        g = _grads(params, 0)

        for maker in (lambda be: scale_by_adam(0.9, 0.95, 1e-8, backend=be),
                      lambda be: scale_by_slim_adam(dims, 0.9, 0.95, 1e-8, backend=be)):
            tx_j, tx_f = maker("jnp"), maker("fused")
            sj, sf = tx_j.init(params), tx_f.init(params)
            for i in range(2):
                gi = _grads(params, i)
                uj, sj = jax.jit(tx_j.update)(gi, sj)
                uf, sf = jax.jit(tx_f.update)(gi, sf)
            _tree_allclose(uj, uf, rtol=1e-5, atol=1e-5)
            _tree_allclose(sj.nu, sf.nu, rtol=1e-5, atol=1e-6)


def test_resolve_backend():
    assert resolve_backend("jnp") == "jnp"
    assert resolve_backend("fused") == "fused"
    assert resolve_backend("auto") in ("jnp", "fused")
    with pytest.raises(ValueError):
        resolve_backend("tpu")
