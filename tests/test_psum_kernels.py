"""Pallas-resident psum regime: partial-stats/finalize kernel parity, owner-
shard moment plans, from-update SNR, and the psum-path dtype boundary.

In-process tests cover the kernels against the jnp oracles (bf16, padded
strips, batched B > 1, both orientations) and the device-free owner-plan
geometry; an 8-host-device subprocess (the pattern from
tests/test_sharded_fused.py) covers shard_map parity of the owner-write/
broadcast scheme vs the single-device fused path, the bf16 psum-state
regression, and the sharded from-update SNR."""
import json
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.kernels.slim_update import (
    slim_finalize,
    slim_finalize_batched,
    slim_partial_stats,
    slim_partial_stats_batched,
    slim_precond_batched,
)
from repro.kernels.snr_stats import snr_update_stats_finalize
from repro.optim import fused as F
from repro.sharding.shardspec import (
    SpecMesh,
    owner_factor,
    owner_placement,
    plan_sharded_leaf,
    regime_counts,
)

KW = dict(b1=0.9, b2=0.95, eps=1e-8, count=3)


def _leaf(shape, axis, seed=0, dtype=jnp.float32):
    k = jax.random.PRNGKey(seed)
    red_ax = 2 if axis == 1 else 1
    g = (jax.random.normal(k, shape) * 0.3).astype(dtype)
    m = jax.random.normal(jax.random.PRNGKey(seed + 1), shape).astype(dtype)
    v_shape = tuple(1 if i == red_ax else s for i, s in enumerate(shape))
    v = jnp.abs(jax.random.normal(jax.random.PRNGKey(seed + 2), v_shape))
    return g, m, v, red_ax


class TestPartialFinalizeKernels:
    # Padded strips (kept % tile != 0), batched B > 1, both orientations.
    CASES = [(1, (2, 5, 33)), (0, (3, 17, 7)), (1, (1, 300, 64)), (0, (4, 64, 129))]

    @pytest.mark.parametrize("axis,shape", CASES)
    def test_partial_stats_matches_reference(self, axis, shape):
        g, m, _, red_ax = _leaf(shape, axis)
        m_new, part = slim_partial_stats_batched(g, m, axis=axis, b1=KW["b1"])
        np.testing.assert_allclose(m_new, KW["b1"] * m + (1 - KW["b1"]) * g,
                                   rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(part, jnp.sum(g * g, axis=red_ax, keepdims=True),
                                   rtol=1e-5, atol=1e-6)

    @pytest.mark.parametrize("axis,shape", CASES)
    def test_partial_stats_snr_lines(self, axis, shape):
        g, m, _, red_ax = _leaf(shape, axis)
        _, _, s1c, s2c, first = slim_partial_stats_batched(
            g, m, axis=axis, b1=KW["b1"], with_snr=True)
        gg = g * g
        f_ref = jax.lax.slice_in_dim(gg, 0, 1, axis=red_ax)
        d = gg - f_ref
        np.testing.assert_allclose(first, f_ref, rtol=1e-6)
        np.testing.assert_allclose(s1c, jnp.sum(d, axis=red_ax, keepdims=True),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(s2c, jnp.sum(d * d, axis=red_ax, keepdims=True),
                                   rtol=1e-5, atol=1e-6)

    @pytest.mark.parametrize("axis,shape", CASES)
    def test_finalize_matches_reference(self, axis, shape):
        g, m, v, red_ax = _leaf(shape, axis)
        n = shape[red_ax]
        m_new, part = slim_partial_stats_batched(g, m, axis=axis, b1=KW["b1"])
        ek = part / n
        u, v_new = slim_finalize_batched(m_new, v, axis=axis, ek=ek, **KW)
        bc1 = 1 - KW["b1"] ** KW["count"]
        bc2 = 1 - KW["b2"] ** KW["count"]
        v_ref = KW["b2"] * v + (1 - KW["b2"]) * ek
        np.testing.assert_allclose(v_new, v_ref, rtol=1e-6)
        np.testing.assert_allclose(
            u, (m_new / bc1) / (jnp.sqrt(v_ref / bc2) + KW["eps"]),
            rtol=1e-5, atol=1e-6)
        # ek=None form: v_line is already the completed moment -> u only
        u2 = slim_finalize_batched(m_new, v_ref, axis=axis, ek=None, **KW)
        np.testing.assert_allclose(u2, u, rtol=1e-6)

    @pytest.mark.parametrize("axis,shape", CASES)
    def test_composition_equals_single_kernel_leaf(self, axis, shape):
        """partial -> local mean -> finalize == the one-kernel precond (the
        unsharded oracle) when the 'psum group' is a single shard."""
        g, m, v, red_ax = _leaf(shape, axis)
        n = shape[red_ax]
        m_new, part = slim_partial_stats_batched(g, m, axis=axis, b1=KW["b1"])
        u, v_new = slim_finalize_batched(m_new, v, axis=axis, ek=part / n, **KW)
        u_ref, m_ref, v_ref = slim_precond_batched(g, m, v, axis=axis, **KW)
        np.testing.assert_allclose(u, u_ref, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(m_new, m_ref, rtol=1e-6)
        np.testing.assert_allclose(v_new, v_ref, rtol=1e-6)

    def test_bf16_inputs(self):
        g, m, v, red_ax = _leaf((2, 9, 40), 1, dtype=jnp.bfloat16)
        m_new, part = slim_partial_stats_batched(g, m, axis=1, b1=KW["b1"])
        assert m_new.dtype == jnp.float32 and part.dtype == jnp.float32
        g32, m32 = g.astype(jnp.float32), m.astype(jnp.float32)
        np.testing.assert_allclose(m_new, KW["b1"] * m32 + (1 - KW["b1"]) * g32,
                                   rtol=1e-2, atol=1e-2)
        np.testing.assert_allclose(part, jnp.sum(g32 * g32, axis=2, keepdims=True),
                                   rtol=1e-2, atol=1e-2)

    def test_2d_wrappers(self):
        g = jax.random.normal(jax.random.PRNGKey(0), (13, 21))
        m = jnp.zeros((13, 21))
        m_new, part = slim_partial_stats(g, m, axis=1)
        assert m_new.shape == (13, 21) and part.shape == (13, 1)
        v = jnp.ones((13, 1))
        u, v_new = slim_finalize(m_new, v, axis=1, ek=part / 21, **KW)
        assert u.shape == (13, 21) and v_new.shape == (13, 1)
        assert slim_finalize(m_new, v_new, axis=1, **KW).shape == (13, 21)


class TestFromUpdateSNR:
    def test_finalize_matches_jnp_oracle(self):
        g, m, v, red_ax = _leaf((2, 7, 48), 1)
        n = 48
        _, part, s1c, s2c, _ = slim_partial_stats_batched(
            g, m, axis=1, b1=KW["b1"], with_snr=True)
        v_new = KW["b2"] * v + (1 - KW["b2"]) * part / n
        snr = snr_update_stats_finalize(v_new, s1c, s2c, n, 1 - KW["b2"])
        ref = F.jnp_update_snr_leaf(g.reshape(14, 48), v_new.reshape(14, 1),
                                    (1,), b2=KW["b2"])
        np.testing.assert_allclose(snr, ref, rtol=1e-4)

    def test_tree_update_emit_snr(self):
        """emit_snr appends per-leaf scalars (None for K = ()) and does not
        perturb the update itself; bucketed small dense leaves included."""
        k = jax.random.PRNGKey(0)
        gs = [jax.random.normal(k, (32, 16)), jax.random.normal(k, (8, 6, 10)),
              jnp.linspace(-1, 1, 64)]
        dims = [(1,), (0,), ()]
        ms = [jnp.zeros(g.shape) for g in gs]
        vs = [jnp.zeros(tuple(1 if i in set(d) else s for i, s in enumerate(g.shape)))
              for g, d in zip(gs, dims)]
        u, m, v, s = F.slim_tree_update(gs, ms, vs, dims, emit_snr=True, **KW)
        u2, _, v2 = F.slim_tree_update(gs, ms, vs, dims, **KW)
        for a, b in zip(u, u2):
            np.testing.assert_allclose(a, b, rtol=1e-6)
        assert s[2] is None
        for i in (0, 1):
            ref = F.jnp_update_snr_leaf(gs[i], v[i], dims[i], b2=KW["b2"])
            np.testing.assert_allclose(s[i], ref, rtol=1e-4)

    def test_measure_tree_snr_from_update(self):
        """The candidate matching the update's K takes the ridden scalar;
        the others fall back to the standard nu measurement."""
        from repro.core.labels import ParamMeta
        from repro.core.snr import measure_tree_snr, snr_along_dims

        meta = {"w": ParamMeta(axes=("embed", "mlp"), role="mlp_up",
                               fan_in=("embed",), fan_out=("mlp",))}
        nu = {"w": jnp.abs(jax.random.normal(jax.random.PRNGKey(0), (8, 16))) + 0.1}
        ridden = jnp.asarray(42.0)
        out = measure_tree_snr(nu, meta, from_update={"w": ridden},
                               update_dims={"w": (0,)})
        assert float(out["w"]["fan_in"]) == 42.0
        np.testing.assert_allclose(out["w"]["fan_out"],
                                   snr_along_dims(nu["w"], (1,)), rtol=1e-6)
        with pytest.raises(ValueError, match="update_dims"):
            measure_tree_snr(nu, meta, from_update={"w": ridden})

    @pytest.mark.slow
    def test_trainer_snr_from_update(self):
        """Measure steps ride the update pass: the candidate matching each
        leaf's K comes from state.snr, the rest match the classic path; the
        published snapshot is stripped after consumption so checkpoints and
        the normal step keep the snr-less layout."""
        sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
        from benchmarks.common import gpt_nano, nano_data
        from repro.train import Trainer, TrainerConfig
        from repro.train.trainer import find_slim_snr

        cfg = gpt_nano()
        base = dict(total_steps=20, log_every=10, measure_snr=True,
                    snr_early_every=10, snr_late_every=100, backend="fused")
        tr = Trainer(cfg, "slim", 3e-3, nano_data(cfg),
                     TrainerConfig(snr_from_update=True, **base))
        tr.run()
        tr2 = Trainer(cfg, "slim", 3e-3, nano_data(cfg), TrainerConfig(**base))
        tr2.run()
        assert tr.snr.count == tr2.snr.count == 2
        assert find_slim_snr(tr.opt_state) is None
        assert tr.metrics_log[-1]["loss"] == tr2.metrics_log[-1]["loss"]
        avg, avg2 = tr.snr.averaged(), tr2.snr.averaged()
        assert avg.keys() == avg2.keys()
        for p, by_k in avg.items():
            assert by_k.keys() == avg2[p].keys()


class TestOwnerPlans:
    MESH = SpecMesh({"data": 4, "model": 2})
    PROD = SpecMesh({"data": 16, "model": 16})

    def test_placement_on_dividing_kept_dim(self):
        owner, nu_spec = owner_placement((16, 1), P(None, None), ("model",), self.MESH)
        assert owner == (("model", 0),)
        assert nu_spec == P("model", None)

    def test_placement_multi_axis(self):
        # both psum axes fit on the same kept dim (16 / (4*2))
        owner, nu_spec = owner_placement((16, 1), P(None, None),
                                         ("data", "model"), self.MESH)
        assert owner == (("data", 0), ("model", 0))
        assert nu_spec == P(("data", "model"), None)

    def test_placement_falls_back_when_indivisible(self):
        # 6 % 4 != 0 and 1-extent dims never take an axis
        owner, nu_spec = owner_placement((6, 1), P(None, None), ("data",), self.MESH)
        assert owner == ()
        assert nu_spec == P(None, None)

    def test_placement_is_all_or_nothing(self):
        """A *partial* placement would corrupt the moment: shards along an
        unplaced psum axis would each add an identical b2*v copy into the
        all-reduce, inflating v_new by that axis's size — so one unplaceable
        psum axis drops the whole placement."""
        # 'data' (4) fits dim0 (4 -> local 1) but 'model' (2) then has no
        # dim left; and in the other order 'model' fits while 'data' fails.
        for axes in (("data", "model"), ("model", "data")):
            owner, nu_spec = owner_placement((4, 1), P(None, None), axes, self.MESH)
            assert owner == () and nu_spec == P(None, None), axes
        # The reviewer case: vocab 50304 takes 16 but not 256.
        owner, nu_spec = owner_placement((50304, 1), P(None, None),
                                         ("data", "model"), self.PROD)
        assert owner == () and nu_spec == P(None, None)

    def test_production_plans(self):
        """The gpt_small psum leaves: owner dedupe everywhere except embed
        (50304 is not divisible by 256), all finalizes kernel-resident."""
        cases = [
            ((12, 768, 12, 64), (1,), P(None, "data", None, None), 16),
            ((12, 3072, 768), (2,), P(None, "model", "data"), 16),
            ((50304, 768), (1,), P("model", "data"), 1),
        ]
        plans = []
        for shape, dims, spec, want in cases:
            pl = plan_sharded_leaf(shape, jnp.float32, dims, spec, self.PROD, n_bufs=5)
            plans.append(pl)
            assert pl.regime == "psum" and pl.finalize == "kernel", (shape, pl)
            assert owner_factor(pl, self.PROD) == want, (shape, pl.owner)
        assert regime_counts(plans) == {"local": 0, "psum": 3, "psum_jnp": 0,
                                        "degraded": 0,
                                        "jnp": 0}

    def test_psum_jnp_counted(self):
        # Interleaved K *after* sharding with a sharded reduced dim: the
        # finalize cannot be kernel-served -> psum_jnp in regime_counts.
        pl = plan_sharded_leaf((4, 6, 8, 10), jnp.float32, (1, 3),
                               P(None, "model", None, None), self.MESH, n_bufs=5)
        assert pl.regime == "psum" and pl.finalize == "jnp"
        assert regime_counts([pl])["psum_jnp"] == 1


PARITY_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from repro.core.slim_adam import scale_by_slim_adam
from repro.optim import fused as F
from repro.sharding.shardspec import owner_factor, regime_counts

mesh = jax.make_mesh((4, 2), ("data", "model"))
key = jax.random.PRNGKey(0)
params = {
    "fanin": jax.random.normal(key, (32, 16)),       # local kernel
    "psum":  jax.random.normal(key, (16, 32)),       # psum, owner on dim0 x model
    "psum3": jax.random.normal(key, (12, 8, 20)),    # batched psum, owner x data
    "psumw": jax.random.normal(key, (6, 8)),         # 2-axis psum group, owner
                                                     # placement fails (6 % 4)
                                                     # -> replicated writes
    "inter": jax.random.normal(key, (4, 6, 8, 10)),  # interleaved -> jnp
    "dense": jax.random.normal(key, (24, 16)),
    "vec":   jnp.linspace(-1.0, 1.0, 64),            # bucket path
}
dims  = {"fanin": (1,), "psum": (1,), "psum3": (2,), "psumw": (1,),
         "inter": (0, 2), "dense": (), "vec": ()}
specs = {"fanin": P("data", None), "psum": P(None, "model"),
         "psum3": P(None, "model", "data"), "psumw": P(None, ("data", "model")),
         "inter": P(), "dense": P("data", "model"), "vec": P("data")}
grads = jax.tree.map(
    lambda p: 0.1 * jax.random.normal(jax.random.PRNGKey(p.size % 13), p.shape), params)

out = {}
gl, td = jax.tree_util.tree_flatten(params)
d_leaves = [tuple(d) for d in td.flatten_up_to(dims)]
plans = F.sharded_tree_plans(gl, d_leaves, td.flatten_up_to(specs), mesh)
out["regimes"] = regime_counts(plans)
out["owner"] = {n: owner_factor(pl, mesh)
                for n, pl in zip(sorted(params), plans) if pl.regime == "psum"}

# owner-write scheme vs single-device fused path, 3 steps (the owner-sharded
# nu layout must round-trip through consecutive updates)
tx1 = scale_by_slim_adam(dims, backend="fused")
tx2 = scale_by_slim_adam(dims, backend="fused", mesh=mesh, param_specs=specs)
s1, s2 = tx1.init(params), tx2.init(params)
for _ in range(3):
    u1, s1 = jax.jit(tx1.update)(grads, s1)
    u2, s2 = jax.jit(tx2.update)(grads, s2)
def errs(t1, t2):
    return {k: float(np.max(np.abs(np.asarray(t1[k]) - np.asarray(t2[k])))) for k in t1}
out["u_err"] = errs(u1, u2)
out["nu_err"] = errs(s1.nu, s2.nu)
out["mu_err"] = errs(s1.mu, s2.mu)

# from-update SNR: sharded == unsharded (psum leaves rebase+psum their stats)
kw = dict(b1=0.9, b2=0.95, eps=1e-8, count=s1.count + 1)
mu_l, nu_l, g_l = (td.flatten_up_to(t) for t in (s1.mu, s1.nu, grads))
_, _, _, snr_a = F.slim_tree_update(g_l, mu_l, nu_l, d_leaves, emit_snr=True, **kw)
mu2_l, nu2_l = td.flatten_up_to(s2.mu), td.flatten_up_to(s2.nu)
spec_l = td.flatten_up_to(specs)
_, _, _, snr_b = jax.jit(lambda g, m, v, c: F.slim_tree_update(
    g, m, v, d_leaves, emit_snr=True, mesh=mesh, spec_leaves=spec_l,
    b1=0.9, b2=0.95, eps=1e-8, count=c))(g_l, mu2_l, nu2_l, s2.count + 1)
out["snr"] = [None if a is None else
              {"single": float(a), "sharded": float(b),
               "rel": abs(float(a) - float(b)) / max(abs(float(a)), 1e-30)}
              for a, b in zip(snr_a, snr_b)]

# dtype regression: bf16 moments stay bf16 through the psum path
g = jax.random.normal(key, (16, 32))
m16 = jnp.zeros((16, 32), jnp.bfloat16)
v16 = jnp.zeros((16, 1), jnp.bfloat16)
ub, mb, vb = jax.jit(lambda *a: F.slim_tree_update(
    [a[0]], [a[1]], [a[2]], [(1,)], b1=0.9, b2=0.95, eps=1e-8, count=1,
    mesh=mesh, spec_leaves=[P(None, "model")]))(g, m16, v16)
out["dtypes"] = [str(ub[0].dtype), str(mb[0].dtype), str(vb[0].dtype)]
print(json.dumps(out))
"""


@pytest.mark.slow
@pytest.mark.multidevice
def test_owner_write_psum_parity(tmp_path):
    """8-device shard_map: the Pallas-resident psum regime with owner-shard
    moment writes matches the single-device fused path — exact on local
    leaves, <= 2e-6 on psum/jnp leaves (fp32 reassociation through the
    combined payload all-reduce) — and bf16 moments keep their dtype."""
    script = tmp_path / "owner_parity.py"
    script.write_text(PARITY_SCRIPT)
    src = str(Path(__file__).resolve().parents[1] / "src")
    proc = subprocess.run([sys.executable, str(script)], capture_output=True,
                          text=True, timeout=900,
                          env={**__import__("os").environ, "PYTHONPATH": src})
    assert proc.returncode == 0, proc.stderr[-3000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])

    assert out["regimes"] == {"local": 3, "psum": 3, "psum_jnp": 0, "jnp": 1,
                              "degraded": 0}
    # owner dedupe engaged where the psum group divides a kept dim; psumw's
    # 2-axis group finds no placement (6 % 4) and must stay replicated —
    # the partial-placement regression (an unplaced psum axis would inflate
    # v_new by its size).
    assert out["owner"] == {"psum": 2, "psum3": 4, "psumw": 1}
    for group in ("u_err", "nu_err", "mu_err"):
        for leaf, err in out[group].items():
            tol = 0.0 if leaf in ("fanin", "dense", "vec") else 2e-6
            assert err <= tol, (group, leaf, err)
    snr = [s for s in out["snr"] if s is not None]
    assert len(snr) == 5  # fanin, inter, psum, psum3, psumw
    for s in snr:
        assert s["rel"] <= 1e-5, s
    assert out["dtypes"] == ["float32", "bfloat16", "bfloat16"]
