"""Megaplan: whole-tree grouped Pallas launches.

Three layers of coverage:

  * planner invariants — segment tables tile each super-tensor injectively,
    groups + jnp fallback partition the leaves, grouping keys are uniform;
  * parity — the grouped path against the per-leaf dispatch
    (``megakernel=False``) on every regime: m/v state bit-exact (the
    concatenation axis is the kept axis, so no reduction line ever crosses
    a segment boundary), u within a couple of fp32 ULP (XLA clones the
    m/v recurrences into the u fusion, and per-fusion FMA contraction
    choices differ between super-tensor and leaf shapes), SNR/health
    riding along;
  * launch counts — the O(leaves) -> O(groups) claim, decided on the jaxpr
    (``count_pallas_launches``), plus the one-sided ``bucket_min_size``
    boundary regression and an 8-device sharded parity subprocess.
"""
import json
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.jaxpr_tools import count_pallas_launches
from repro.core.slim_adam import scale_by_slim_adam
from repro.kernels.fused_adam import LANES
from repro.kernels.megaplan import (gather_group, plan_megagroups,
                                    scatter_group, segment_table)
from repro.kernels.slim_update import PRECOND_BUFS
from repro.kernels.tiling import VMEM_BUDGET
from repro.optim import fused, scale_by_adam


def _mixed_tree():
    """One leaf per regime: two same-cols minor leaves (one ragged), a major
    leaf, a scan-stacked batched leaf, an interleaved-K jnp-route leaf, a
    bf16 leaf sharing the minor group, dense odd/scalar/vector leaves, and a
    size-1-kept leaf."""
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 10)
    params = {
        "minor_a": jax.random.normal(ks[0], (24, 16)),
        "minor_b": jax.random.normal(ks[1], (7, 16)),
        "bf16": jax.random.normal(ks[2], (9, 16)).astype(jnp.bfloat16),
        "major": jax.random.normal(ks[3], (16, 24)),
        "batched": jax.random.normal(ks[4], (3, 8, 6, 4)),
        "inter": jax.random.normal(ks[5], (4, 6, 10)),
        "dense_odd": jax.random.normal(ks[6], (33, 5)),
        "scalar": jnp.asarray(0.5),
        "size1": jax.random.normal(ks[7], (1, 4)),
        "vec": jax.random.normal(ks[8], (37,)),
    }
    dims = {"minor_a": (1,), "minor_b": (1,), "bf16": (1,), "major": (0,),
            "batched": (1,), "inter": (0, 2), "dense_odd": (), "scalar": (),
            "size1": (1,), "vec": (0,)}
    return params, dims


def _leaf_geometry(params, dims):
    leaves, treedef = jax.tree_util.tree_flatten(params)
    d_leaves = tuple(tuple(d) for d in treedef.flatten_up_to(dims))
    return (tuple(tuple(p.shape) for p in leaves),
            tuple(str(p.dtype) for p in leaves), d_leaves)


def _grads(params, i):
    k = jax.random.PRNGKey(100 + i)
    return jax.tree.map(
        lambda x: 0.1 * jax.random.normal(k, x.shape).astype(x.dtype), params)


def _tree_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        assert x.shape == y.shape and x.dtype == y.dtype
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _tree_close_ulp(a, b):
    # u only: the two compilations may contract the cloned m/v recurrences
    # into FMAs differently inside the u fusion — a couple of ULP, no more.
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        assert x.shape == y.shape and x.dtype == y.dtype
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=5e-7, atol=5e-7)


class TestPlanner:
    def test_groups_partition_leaves(self):
        shapes, dts, d_leaves = _leaf_geometry(*_mixed_tree())
        plan = plan_megagroups(shapes, dts, d_leaves, n_bufs=PRECOND_BUFS)
        covered = list(plan.jnp_idx)
        for g in plan.groups:
            off = 0
            for seg in g.segments:
                assert seg.length > 0
                assert seg.offset == off
                off += seg.length
                covered.append(seg.index)
            assert off == g.extent
        assert sorted(covered) == list(range(len(shapes)))

    def test_same_geometry_leaves_share_a_group(self):
        shapes, dts, d_leaves = _leaf_geometry(*_mixed_tree())
        plan = plan_megagroups(shapes, dts, d_leaves, n_bufs=PRECOND_BUFS)
        by_kind = {}
        for g in plan.groups:
            by_kind.setdefault(g.kind, []).append(g)
        # minor_a, minor_b, bf16, size1 all reduce 16/4-col rows... only the
        # cols-16 leaves share; dtype must never split a group (bf16 rides
        # with f32 — the gather casts).
        minor_cols = {g.cols: len(g.segments) for g in by_kind["minor"]}
        assert minor_cols[16] == 3   # minor_a + minor_b + bf16
        assert len(by_kind["dense"]) == 1
        assert len(by_kind["batched"]) == 1

    def test_fallback_and_interleaved_routing(self):
        """0-d leaves take the jnp route; an interleaved-K leaf
        canonicalizes (permute + reshape) into an ordinary minor group."""
        shapes, dts, d_leaves = _leaf_geometry(*_mixed_tree())
        plan = plan_megagroups(shapes, dts, d_leaves, n_bufs=PRECOND_BUFS)
        names = sorted(_mixed_tree()[0])
        assert [names[i] for i in plan.jnp_idx] == ["scalar"]
        inter = [g for g in plan.groups
                 if any(names[s.index] == "inter" for s in g.segments)]
        assert len(inter) == 1 and inter[0].kind == "minor"

    def test_dense_lane_folding(self):
        shapes, dts, d_leaves = _leaf_geometry(*_mixed_tree())
        plan = plan_megagroups(shapes, dts, tuple(() for _ in shapes))
        (g,) = plan.groups
        assert g.kind == "dense" and g.cols == LANES
        for seg in g.segments:
            assert seg.length == -(-int(np.prod(seg.shape)) // LANES)

    def test_segment_table_contents(self):
        shapes, dts, d_leaves = _leaf_geometry(*_mixed_tree())
        plan = plan_megagroups(shapes, dts, d_leaves, n_bufs=PRECOND_BUFS)
        for g in plan.groups:
            tbl = segment_table(g)
            assert tbl.shape == (g.extent, 4)
            exp = np.repeat(np.asarray([s.index for s in g.segments]),
                            np.asarray([s.length for s in g.segments]))
            np.testing.assert_array_equal(tbl[:, 0], exp)
            assert (tbl[:, 2] > 0).all()

    def test_plan_is_cached(self):
        shapes, dts, d_leaves = _leaf_geometry(*_mixed_tree())
        a = plan_megagroups(shapes, dts, d_leaves, n_bufs=PRECOND_BUFS)
        b = plan_megagroups(shapes, dts, d_leaves, n_bufs=PRECOND_BUFS)
        assert a is b

    def test_gather_scatter_roundtrip(self):
        params, dims = _mixed_tree()
        shapes, dts, d_leaves = _leaf_geometry(params, dims)
        xs = jax.tree.leaves(params)
        plan = plan_megagroups(shapes, dts, d_leaves, n_bufs=PRECOND_BUFS)
        for g in plan.groups:
            y = gather_group(g, xs)
            back = scatter_group(g, y)
            for seg, arr in zip(g.segments, back):
                np.testing.assert_array_equal(
                    np.asarray(arr),
                    np.asarray(xs[seg.index].astype(jnp.float32)))


class TestMegaParity:
    """Grouped vs per-leaf dispatch: state bit-for-bit (concatenation is
    along the kept axis, so each reduction line's arithmetic is unchanged),
    u within a couple of ULP (see module docstring)."""

    def test_adam_tree_parity(self):
        params, _ = _mixed_tree()
        tx_m = scale_by_adam(0.9, 0.95, 1e-8, backend="fused")
        tx_p = scale_by_adam(0.9, 0.95, 1e-8, backend="fused",
                             megakernel=False, bucket_min_size=0)
        sm, sp = tx_m.init(params), tx_p.init(params)
        for i in range(2):
            g = _grads(params, i)
            um, sm = jax.jit(tx_m.update)(g, sm)
            up, sp = jax.jit(tx_p.update)(g, sp)
        _tree_close_ulp(um, up)
        _tree_equal(sm.mu, sp.mu)
        _tree_equal(sm.nu, sp.nu)

    def test_slim_tree_parity(self):
        params, dims = _mixed_tree()
        tx_m = scale_by_slim_adam(dims, 0.9, 0.95, 1e-8, backend="fused")
        tx_p = scale_by_slim_adam(dims, 0.9, 0.95, 1e-8, backend="fused",
                                  megakernel=False, bucket_min_size=0)
        sm, sp = tx_m.init(params), tx_p.init(params)
        for i in range(2):
            g = _grads(params, i)
            um, sm = jax.jit(tx_m.update)(g, sm)
            up, sp = jax.jit(tx_p.update)(g, sp)
        _tree_close_ulp(um, up)
        _tree_equal(sm.mu, sp.mu)
        _tree_equal(sm.nu, sp.nu)

    def test_snr_and_health_ride_along(self):
        params, dims = _mixed_tree()
        mk = lambda mega: scale_by_slim_adam(
            dims, 0.9, 0.95, 1e-8, backend="fused", emit_snr=True,
            emit_health=True, megakernel=mega,
            **({} if mega else {"bucket_min_size": 0}))
        tx_m, tx_p = mk(True), mk(False)
        sm, sp = tx_m.init(params), tx_p.init(params)
        g = _grads(params, 0)
        # poison one leaf so the non-finite count is exercised, not just zero
        g = dict(g, minor_a=g["minor_a"].at[0, 0].set(jnp.nan))
        um, sm = jax.jit(tx_m.update)(g, sm)
        up, sp = jax.jit(tx_p.update)(g, sp)
        for a, b in zip(jax.tree.leaves(sm.snr), jax.tree.leaves(sp.snr)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6, atol=0)
        np.testing.assert_array_equal(np.asarray(sm.health.nonfinite),
                                      np.asarray(sp.health.nonfinite))
        # grad sumsq differs only in float summation order across segments
        np.testing.assert_allclose(np.asarray(sm.health.grad_sumsq),
                                   np.asarray(sp.health.grad_sumsq),
                                   rtol=1e-5)

    @pytest.mark.slow
    def test_fit_edge_leaf(self):
        """A reduction line landing exactly on the VMEM fit boundary must
        still group (one-sided fits-gate) and stay bit-exact."""
        red = VMEM_BUDGET // (4 * PRECOND_BUFS)
        params = {"edge": jnp.ones((2, red)), "mate": jnp.ones((3, red))}
        dims = {"edge": (1,), "mate": (1,)}
        shapes, dts, d_leaves = _leaf_geometry(params, dims)
        plan = plan_megagroups(shapes, dts, d_leaves, n_bufs=PRECOND_BUFS)
        assert len(plan.groups) == 1 and not plan.jnp_idx
        tx_m = scale_by_slim_adam(dims, backend="fused")
        tx_p = scale_by_slim_adam(dims, backend="fused", megakernel=False,
                                  bucket_min_size=0)
        sm, sp = tx_m.init(params), tx_p.init(params)
        g = _grads(params, 0)
        um, sm = jax.jit(tx_m.update)(g, sm)
        up, sp = jax.jit(tx_p.update)(g, sp)
        _tree_close_ulp(um, up)
        _tree_equal(sm.nu, sp.nu)


class TestLaunchCounts:
    def test_mega_launches_equal_groups(self):
        params, dims = _mixed_tree()
        shapes, dts, d_leaves = _leaf_geometry(params, dims)
        plan = plan_megagroups(shapes, dts, d_leaves, n_bufs=PRECOND_BUFS)
        g = _grads(params, 0)

        def launches(tx):
            s = tx.init(params)
            return count_pallas_launches(
                lambda gg, ss, tx=tx: tx.update(gg, ss), g, s)

        n_mega = launches(scale_by_slim_adam(dims, backend="fused"))
        n_per = launches(scale_by_slim_adam(dims, backend="fused",
                                            megakernel=False,
                                            bucket_min_size=0))
        assert n_mega == len(plan.groups)
        assert n_mega < n_per

    def test_bucket_min_size_boundary_one_sided(self):
        """The boundary is strict everywhere: a leaf of size exactly
        ``bucket_min_size`` is NOT bucketed (``size < bucket_min_size``),
        one element below is."""
        assert not fused._bucket_eligible(64, 64)
        assert fused._bucket_eligible(63, 64)
        assert not fused._bucket_eligible(64, 0)   # 0 disables bucketing

        params = {"a": jnp.ones((8, 8)), "b": jnp.ones((8, 8))}  # size 64
        g = _grads(params, 0)

        def launches(bms):
            tx = scale_by_adam(backend="fused", megakernel=False,
                               bucket_min_size=bms)
            s = tx.init(params)
            return count_pallas_launches(
                lambda gg, ss, tx=tx: tx.update(gg, ss), g, s)

        assert launches(64) == 2   # at the boundary: per-leaf launches
        assert launches(65) == 1   # strictly below: one bucket launch


_SHARDED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from repro.analysis.jaxpr_tools import count_pallas_launches
from repro.core.slim_adam import scale_by_slim_adam
from repro.optim import fused as F
from repro.optim.adam import scale_by_adam
from repro.sharding.shardspec import regime_counts

mesh = jax.make_mesh((4, 2), ("data", "model"))
key = jax.random.PRNGKey(0)
params = {
    "own_a": jax.random.normal(key, (16, 32)),   # psum, owner-placed
    "own_b": jax.random.normal(key, (8, 32)),    # psum owner, same line geometry
    "plain": jax.random.normal(key, (15, 32)),   # psum, placement fails (15 odd)
    "local": jax.random.normal(key, (32, 16)),   # local minor
    "dense": jax.random.normal(key, (24, 16)),   # K=() dense
    "inter": jax.random.normal(key, (4, 6, 8, 10)),  # jnp fallback
}
dims  = {"own_a": (1,), "own_b": (1,), "plain": (1,), "local": (1,),
         "dense": (), "inter": (0, 2)}
specs = {"own_a": P(None, "model"), "own_b": P(None, "model"),
         "plain": P(None, "model"), "local": P("data", None),
         "dense": P("data", "model"), "inter": P()}
grads = jax.tree.map(
    lambda p: 0.1 * jax.random.normal(jax.random.PRNGKey(p.size % 13), p.shape),
    params)

gl, td = jax.tree_util.tree_flatten(params)
plans = F.sharded_tree_plans(gl, [tuple(d) for d in td.flatten_up_to(dims)],
                             td.flatten_up_to(specs), mesh)
out = {"regimes": regime_counts(plans),
       "owner": {k: bool(pl.owner) for k, pl in
                 zip(sorted(params), plans) if pl.regime == "psum"}}

def leaf_errs(u1, u2):
    return {k: float(np.max(np.abs(np.asarray(u1[k], np.float32)
                                   - np.asarray(u2[k], np.float32))))
            for k in u1}

mk = lambda mega, **kw: scale_by_slim_adam(
    dims, backend="fused", mesh=mesh, param_specs=specs, megakernel=mega,
    **({} if mega else {"bucket_min_size": 0}), **kw)

tx_m, tx_p = mk(True, emit_snr=True, emit_health=True), \
             mk(False, emit_snr=True, emit_health=True)
sm, sp = tx_m.init(params), tx_p.init(params)
for _ in range(2):
    um, sm = jax.jit(tx_m.update)(grads, sm)
    up, sp = jax.jit(tx_p.update)(grads, sp)
out["slim_u"] = leaf_errs(um, up)
out["slim_nu"] = leaf_errs(sm.nu, sp.nu)
out["snr"] = {k: [float(a), float(b)]
              for k, a, b in ((k, sm.snr[k], sp.snr[k]) for k in sm.snr)
              if a is not None}
out["health_nonfinite_equal"] = bool(np.array_equal(
    np.asarray(sm.health.nonfinite), np.asarray(sp.health.nonfinite)))

ta_m = scale_by_adam(backend="fused", mesh=mesh, param_specs=specs)
ta_p = scale_by_adam(backend="fused", mesh=mesh, param_specs=specs,
                     megakernel=False, bucket_min_size=0)
am, ap = ta_m.init(params), ta_p.init(params)
uam, am = jax.jit(ta_m.update)(grads, am)
uap, ap = jax.jit(ta_p.update)(grads, ap)
out["adam_u"] = leaf_errs(uam, uap)

out["launches"] = {
    "slim_mega": count_pallas_launches(lambda g, s: tx_m.update(g, s), grads, sm),
    "slim_perleaf": count_pallas_launches(lambda g, s: tx_p.update(g, s), grads, sp),
    "adam_mega": count_pallas_launches(lambda g, s: ta_m.update(g, s), grads, am),
    "adam_perleaf": count_pallas_launches(lambda g, s: ta_p.update(g, s), grads, ap),
}
print(json.dumps(out))
print("MEGA_SHARDED_OK")
"""


@pytest.mark.slow
@pytest.mark.multidevice
def test_sharded_mega_parity(tmp_path):
    """8-host-device shard_map: the grouped psum pair (owner and plain forms
    partitioned into separate groups, per-leaf collectives between the two
    launches) must match the per-leaf sharded dispatch — state exact up to
    psum-line contraction slack, u within ULP noise — with fewer launches."""
    script = tmp_path / "sharded_mega.py"
    script.write_text(_SHARDED_SCRIPT)
    src = str(Path(__file__).resolve().parents[1] / "src")
    proc = subprocess.run([sys.executable, str(script)], capture_output=True,
                          text=True,
                          env={**__import__("os").environ, "PYTHONPATH": src},
                          timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "MEGA_SHARDED_OK" in proc.stdout
    out = json.loads(proc.stdout.strip().splitlines()[-2])

    assert out["regimes"] == {"local": 2, "psum": 3, "psum_jnp": 0,
                              "jnp": 1, "degraded": 0}, out["regimes"]
    assert out["owner"] == {"own_a": True, "own_b": True, "plain": False}

    psum_leaves = {"own_a", "own_b", "plain"}
    for leaf, err in out["slim_nu"].items():
        # psum lines: the partial-stat recurrence is cloned into the
        # finalize fusion, so contraction slack applies there too
        bound = 1e-9 if leaf in psum_leaves else 0.0
        assert err <= bound, ("slim_nu", leaf, err)
    for group in ("slim_u", "adam_u"):   # couple-of-ULP FMA slack, as above
        for leaf, err in out[group].items():
            assert err <= 2e-6, (group, leaf, err)
    for leaf, (a, b) in out["snr"].items():
        np.testing.assert_allclose(a, b, rtol=1e-6)
    assert out["health_nonfinite_equal"]

    n = out["launches"]
    assert n["slim_mega"] < n["slim_perleaf"], n
    assert n["adam_mega"] < n["adam_perleaf"], n
    assert n["adam_mega"] == 1, n
