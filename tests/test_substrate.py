"""Data pipeline, checkpointing (incl. resharding restore), trainer, serving."""
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import store
from repro.configs import get_reduced
from repro.data import DataConfig, ZipfLM
from repro.serve import Engine, ServeConfig
from repro.train import (Trainer, TrainerConfig, inject_checkpoint_io_failure,
                         tear_checkpoint)


class TestData:
    def test_deterministic_across_restarts(self):
        cfg = DataConfig(vocab_size=101, seq_len=16, global_batch=4, seed=7)
        a = ZipfLM(cfg).batch(12)
        b = ZipfLM(cfg).batch(12)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_distinct_steps_distinct_data(self):
        gen = ZipfLM(DataConfig(vocab_size=101, seq_len=16, global_batch=4))
        assert not np.array_equal(gen.batch(0)["tokens"], gen.batch(1)["tokens"])

    def test_host_sharding_partitions_batch(self):
        cfg = DataConfig(vocab_size=101, seq_len=8, global_batch=8, seed=3)
        gen = ZipfLM(cfg)
        h0 = gen.batch(5, host_id=0, host_count=4)
        h1 = gen.batch(5, host_id=1, host_count=4)
        assert h0["tokens"].shape == (2, 8)
        assert not np.array_equal(h0["tokens"], h1["tokens"])

    def test_labels_are_shifted_tokens(self):
        gen = ZipfLM(DataConfig(vocab_size=101, seq_len=16, global_batch=2))
        b = gen.batch(0)
        # labels[t] continues tokens[t]: they come from one contiguous stream
        assert b["tokens"].shape == b["labels"].shape
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_heavy_tail(self):
        """Zipf alpha controls tail mass: token 0 must dominate."""
        gen = ZipfLM(DataConfig(vocab_size=1000, seq_len=64, global_batch=8, alpha=1.3))
        toks = gen.batch(0)["tokens"].ravel()
        counts = np.bincount(toks, minlength=1000)
        assert counts[:10].sum() > counts[500:].sum()


class TestCheckpoint:
    def _tree(self, key=0):
        k = jax.random.PRNGKey(key)
        return {"params": {"w": jax.random.normal(k, (8, 4)), "b": jnp.zeros((4,))},
                "count": jnp.asarray(5, jnp.int32)}

    def test_roundtrip(self, tmp_path):
        t = self._tree()
        store.save(tmp_path, 100, t, extra={"step": 100})
        restored, extra = store.restore(tmp_path, t)
        assert extra["step"] == 100
        for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_latest_pointer_and_gc(self, tmp_path):
        t = self._tree()
        for s in (10, 20, 30, 40):
            store.save(tmp_path, s, t, keep=2)
        assert store.latest_step(tmp_path) == 40
        kept = sorted(p.name for p in tmp_path.glob("step_*"))
        assert kept == ["step_00000030", "step_00000040"]

    def test_shape_mismatch_rejected(self, tmp_path):
        t = self._tree()
        store.save(tmp_path, 1, t)
        bad = {"params": {"w": jnp.zeros((9, 4)), "b": jnp.zeros((4,))},
               "count": jnp.asarray(0, jnp.int32)}
        with pytest.raises(ValueError):
            store.restore(tmp_path, bad)

    def test_async_checkpointer(self, tmp_path):
        t = self._tree()
        acp = store.AsyncCheckpointer()
        acp.save(tmp_path, 7, t)
        acp.wait()
        assert store.latest_step(tmp_path) == 7

    def test_async_checkpointer_overlapping_saves(self, tmp_path, monkeypatch):
        """Two in-flight saves: wait() must join *both* threads (the old
        single-slot tracking joined only the newest, orphaning the other
        mid-write), so both checkpoints are on disk afterwards."""
        real_save = store.save
        slowed = []

        def slow_save(ckpt_dir, step, tree, **kw):
            if not slowed:           # whichever thread runs first is delayed
                slowed.append(step)
                time.sleep(0.3)
            return real_save(ckpt_dir, step, tree, **kw)

        monkeypatch.setattr(store, "save", slow_save)
        t = self._tree()
        acp = store.AsyncCheckpointer()
        acp.save(tmp_path, 7, t)
        acp.save(tmp_path, 9, t)
        assert len(acp._threads) == 2   # both tracked, not last-writer-wins
        acp.wait()
        assert (tmp_path / "step_00000007" / "manifest.json").exists()
        assert (tmp_path / "step_00000009" / "manifest.json").exists()
        assert store.latest_step(tmp_path) in (7, 9)
        acp.wait()                      # idempotent after pruning


class TestHardenedCheckpoint:
    _tree = TestCheckpoint._tree

    def test_torn_newest_falls_back_to_previous(self, tmp_path):
        """A checkpoint torn mid-write (truncated arrays, wrong checksums)
        must be skipped with a warning and the previous valid step restored."""
        t = self._tree()
        store.save(tmp_path, 1, t, extra={"step": 1})
        store.save(tmp_path, 2, t, extra={"step": 2})
        torn = tear_checkpoint(tmp_path)
        assert torn == 2
        with pytest.warns(UserWarning, match="falling back"):
            _, extra = store.restore(tmp_path, t)
        assert extra["step"] == 1

    def test_explicit_step_checksum_mismatch_raises(self, tmp_path):
        """step=... is a demand for *that* checkpoint: a checksum mismatch
        raises (naming the bad leaf) instead of silently falling back."""
        t = self._tree()
        store.save(tmp_path, 3, t)
        mpath = tmp_path / "step_00000003" / "manifest.json"
        manifest = json.loads(mpath.read_text())
        leaf = next(iter(manifest["leaves"]))
        manifest["leaves"][leaf]["crc32"] = (
            manifest["leaves"][leaf]["crc32"] + 1) % (1 << 32)
        mpath.write_text(json.dumps(manifest))
        with pytest.raises(store.ChecksumError, match=f"leaf '{leaf}' crc32"):
            store.restore(tmp_path, t, step=3)
        # ChecksumError is a ValueError: strict callers keep working
        assert issubclass(store.ChecksumError, ValueError)

    def test_no_valid_checkpoint_raises_filenotfound(self, tmp_path):
        t = self._tree()
        store.save(tmp_path, 1, t)
        tear_checkpoint(tmp_path)
        with pytest.warns(UserWarning, match="falling back"):
            with pytest.raises(FileNotFoundError):
                store.restore(tmp_path, t)

    def test_stale_tmp_dir_invisible(self, tmp_path):
        """`step-<n>.tmp` staging dirs never match the `step_*` glob, so a
        crash mid-save can't surface a half-written checkpoint; the next
        save of that step clears the stale staging dir."""
        t = self._tree()
        store.save(tmp_path, 5, t, extra={"step": 5})
        stale = tmp_path / "step-00000006.tmp"
        stale.mkdir()
        (stale / "manifest.json").write_text("{not json")
        assert store.latest_step(tmp_path) == 5
        _, extra = store.restore(tmp_path, t)
        assert extra["step"] == 5
        store.save(tmp_path, 6, t)          # clears + replaces the stale tmp
        assert store.latest_step(tmp_path) == 6
        assert not stale.exists()

    def test_async_failure_reraised_with_step(self, tmp_path, monkeypatch):
        """A worker-thread save failure must not vanish: the first failure is
        re-raised (naming the step) on the next wait()/save()."""
        def boom(ckpt_dir, step, tree, **kw):
            raise RuntimeError("disk on fire")

        monkeypatch.setattr(store, "save", boom)
        acp = store.AsyncCheckpointer(max_retries=0, backoff_s=0.01)
        acp.save(tmp_path, 7, self._tree())
        with pytest.raises(RuntimeError, match="step 7"):
            acp.wait()
        monkeypatch.undo()
        acp.save(tmp_path, 8, self._tree())  # failure cleared: next save works
        acp.wait()
        assert store.latest_step(tmp_path) == 8

    def test_async_retries_transient_io_error(self, tmp_path):
        """One injected OSError on the first write attempt: the worker
        retries with backoff and the checkpoint still lands."""
        acp = store.AsyncCheckpointer(max_retries=2, backoff_s=0.01)
        import warnings as _w
        with _w.catch_warnings():
            _w.simplefilter("ignore")
            with inject_checkpoint_io_failure(fail_on=(1,)) as io_state:
                acp.save(tmp_path, 11, self._tree())
                acp.wait()                   # must not raise: retry succeeded
        assert io_state["failed"] == 1
        assert io_state["calls"] >= 2
        assert store.latest_step(tmp_path) == 11

    def test_pre_checksum_checkpoints_still_restore(self, tmp_path):
        """Checkpoints written before the crc32 field existed (no "crc32" in
        the manifest leaves) must restore without checksum verification."""
        t = self._tree()
        store.save(tmp_path, 4, t, extra={"step": 4})
        mpath = tmp_path / "step_00000004" / "manifest.json"
        manifest = json.loads(mpath.read_text())
        for leaf in manifest["leaves"].values():
            leaf.pop("crc32")
        mpath.write_text(json.dumps(manifest))
        restored, extra = store.restore(tmp_path, t)
        assert extra["step"] == 4
        for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestTrainerFaultTolerance:
    def _mk(self, tmp_path, steps=10):
        cfg = get_reduced("smollm_135m")
        data = ZipfLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=4))
        tc = TrainerConfig(total_steps=steps, log_every=5, ckpt_every=5,
                           ckpt_dir=str(tmp_path), seed=0)
        return cfg, data, tc

    @pytest.mark.slow
    def test_preemption_resume_identical(self, tmp_path):
        """Train 10; separately train 5 -> 'preempt' -> resume 5 more. The
        deterministic data pipeline makes the trajectories identical."""
        cfg, data, tc = self._mk(tmp_path / "a", steps=10)
        t_full = Trainer(cfg, "adam", 1e-3, data, tc)
        t_full.run()

        cfg2, data2, tc2 = self._mk(tmp_path / "b", steps=5)
        t_half = Trainer(cfg2, "adam", 1e-3, data2, tc2)
        t_half.run()
        del t_half  # preemption

        tc3 = TrainerConfig(total_steps=10, log_every=5, ckpt_every=5,
                            ckpt_dir=str(tmp_path / "b"), seed=0)
        t_resumed = Trainer(cfg2, "adam", 1e-3, data2, tc3)
        assert t_resumed.step == 5
        t_resumed.run()
        for a, b in zip(jax.tree.leaves(t_full.params), jax.tree.leaves(t_resumed.params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)

    def test_restore_past_target_returns_metrics(self, tmp_path):
        """A restored checkpoint already at/past the requested step count:
        run() used to return {} (crashing callers indexing last["loss"]); it
        must return a well-formed no-op metrics dict instead."""
        cfg, data, tc = self._mk(tmp_path, steps=5)
        Trainer(cfg, "adam", 1e-3, data, tc).run()

        t2 = Trainer(cfg, "adam", 1e-3, data, tc)   # restores step=5
        assert t2.step == 5
        last = t2.run(steps=3)                       # target already passed
        assert t2.step == 5                          # no training happened
        for key in ("loss", "grad_norm", "step", "wall_s"):
            assert key in last
        assert last["step"] == 5 and last["grad_norm"] == 0.0
        assert np.isfinite(last["loss"])


class TestServe:
    def test_batched_generation(self):
        cfg = get_reduced("smollm_135m")
        params, _ = cfg.init(jax.random.PRNGKey(0))
        eng = Engine(cfg, params, ServeConfig(max_new_tokens=6, max_seq=32))
        prompts = jax.random.randint(jax.random.PRNGKey(1), (3, 4), 0, cfg.vocab_size)
        out = eng.generate(prompts)
        assert out.shape == (3, 10)
        np.testing.assert_array_equal(np.asarray(out[:, :4]), np.asarray(prompts))

    def test_greedy_matches_decode_argmax(self):
        cfg = get_reduced("falcon_mamba_7b")
        params, _ = cfg.init(jax.random.PRNGKey(0))
        eng = Engine(cfg, params, ServeConfig(max_new_tokens=3, max_seq=32, temperature=0.0))
        prompts = jnp.array([[1, 2, 3]], dtype=jnp.int32)
        out1 = eng.generate(prompts)
        out2 = eng.generate(prompts)
        np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))

    def test_cache_overflow_truncates_with_warning(self):
        """prompt + max_new_tokens > max_seq used to write past the KV/SSM
        cache (dynamic_update_slice clamps, silently overwriting the last
        slot). The engine must truncate generation to fit instead."""
        cfg = get_reduced("smollm_135m")
        params, _ = cfg.init(jax.random.PRNGKey(0))
        eng = Engine(cfg, params, ServeConfig(max_new_tokens=64, max_seq=8))
        prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 4), 0, cfg.vocab_size)
        with pytest.warns(UserWarning, match="truncating max_new_tokens"):
            out = eng.generate(prompts)
        assert out.shape == (2, 8)  # 4 prompt + 4 generated = max_seq

    def test_prompt_filling_cache_rejected(self):
        cfg = get_reduced("smollm_135m")
        params, _ = cfg.init(jax.random.PRNGKey(0))
        eng = Engine(cfg, params, ServeConfig(max_new_tokens=4, max_seq=4))
        prompts = jnp.zeros((1, 6), jnp.int32)
        with pytest.raises(ValueError, match="no room"):
            eng.generate(prompts)

    def test_eos_stops_decode_early(self):
        """Once every row has emitted eos, the decode loop must stop instead
        of burning max_new_tokens steps; rows that finished stay pinned at
        eos for whatever suffix is emitted."""
        cfg = get_reduced("smollm_135m")
        params, _ = cfg.init(jax.random.PRNGKey(0))
        eng = Engine(cfg, params, ServeConfig(max_new_tokens=16, max_seq=32))
        prompts = jnp.array([[1, 2, 3]], dtype=jnp.int32)
        free = eng.generate(prompts)             # no eos: full length
        assert free.shape == (1, 3 + 16)
        eos = int(free[0, 3])                    # greedy first token == eos
        out = eng.generate(prompts, eos_id=eos)
        assert out.shape[1] == 4                 # stopped right after eos
        assert int(out[0, -1]) == eos

    def test_wall_clock_budget_prefill_degrades_to_prompt(self):
        """max_wall_s exhausted during prefill: the engine can't emit
        anything sensible, so it returns the prompt unchanged (with a
        warning) instead of hanging past its latency budget."""
        cfg = get_reduced("smollm_135m")
        params, _ = cfg.init(jax.random.PRNGKey(0))
        eng = Engine(cfg, params, ServeConfig(max_new_tokens=8, max_seq=32,
                                              max_wall_s=0.0))
        prompts = jnp.array([[1, 2, 3, 4]], dtype=jnp.int32)
        with pytest.warns(UserWarning, match="wall-clock budget.*prefill"):
            out = eng.generate(prompts)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(prompts))

    def test_wall_clock_budget_truncates_decode(self):
        """Budget exhausted mid-decode: return what was generated so far
        (truncated, warned) rather than the full max_new_tokens."""
        cfg = get_reduced("smollm_135m")
        params, _ = cfg.init(jax.random.PRNGKey(0))
        eng = Engine(cfg, params, ServeConfig(max_new_tokens=32, max_seq=64))
        prompts = jnp.array([[1, 2]], dtype=jnp.int32)
        eng.generate(prompts)                    # warm the jit caches
        real_decode = eng._decode

        def slow_decode(params, cache, tok):
            time.sleep(0.05)
            return real_decode(params, cache, tok)

        eng._decode = slow_decode
        eng.sc = dataclasses.replace(eng.sc, max_wall_s=0.5)
        with pytest.warns(UserWarning, match="truncated response"):
            out = eng.generate(prompts)
        assert 2 < out.shape[1] < 2 + 32         # some tokens, not all
