"""Model-component oracles: flash attention, selective scan, MoE, norms."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or deterministic fallback

from repro.models.attention import chunked_attention, dense_attention, flash_attention
from repro.models.common import init_params, layer_norm, normal_init, rms_norm
from repro.models.mlp_moe import MoEConfig, moe_forward, moe_specs
from repro.models.ssm import selective_scan


class TestFlashAttention:
    @pytest.mark.parametrize("b,s,h,hd,causal,blk", [
        (2, 256, 4, 32, True, 64),
        (1, 128, 2, 16, False, 32),
        (2, 512, 3, 8, True, 128),
        (1, 192, 1, 64, True, 48),
    ])
    def test_forward_matches_dense(self, b, s, h, hd, causal, blk):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q, k, v = (jax.random.normal(kk, (b, s, h, hd)) for kk in ks)
        np.testing.assert_allclose(flash_attention(q, k, v, causal, blk),
                                   dense_attention(q, k, v, causal=causal),
                                   atol=3e-5, rtol=3e-5)

    def test_gradients_match_dense(self):
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q, k, v = (jax.random.normal(kk, (2, 128, 2, 16)) for kk in ks)

        def loss(f):
            return lambda q, k, v: jnp.sum(jnp.sin(f(q, k, v)))

        g1 = jax.grad(loss(lambda q, k, v: flash_attention(q, k, v, True, 32)), (0, 1, 2))(q, k, v)
        g2 = jax.grad(loss(lambda q, k, v: dense_attention(q, k, v, causal=True)), (0, 1, 2))(q, k, v)
        for a, b_ in zip(g1, g2):
            np.testing.assert_allclose(a, b_, atol=5e-5, rtol=5e-5)

    def test_chunked_matches_dense(self):
        ks = jax.random.split(jax.random.PRNGKey(2), 3)
        q, k, v = (jax.random.normal(kk, (1, 64, 2, 8)) for kk in ks)
        np.testing.assert_allclose(chunked_attention(q, k, v, causal=True, kv_block=16),
                                   dense_attention(q, k, v, causal=True), atol=3e-5, rtol=3e-5)

    @settings(max_examples=10, deadline=None)
    @given(st.integers(min_value=1, max_value=4), st.sampled_from([32, 64, 96]))
    def test_causality_property(self, b, s):
        """Perturbing future keys/values never changes past outputs."""
        ks = jax.random.split(jax.random.PRNGKey(b * s), 3)
        q, k, v = (jax.random.normal(kk, (b, s, 2, 8)) for kk in ks)
        out1 = flash_attention(q, k, v, True, 32)
        k2 = k.at[:, s // 2:].add(10.0)
        v2 = v.at[:, s // 2:].add(-3.0)
        out2 = flash_attention(q, k2, v2, True, 32)
        np.testing.assert_allclose(out1[:, : s // 2], out2[:, : s // 2], atol=1e-5)


class TestSelectiveScan:
    def _ref(self, x, dt, a, b_t, c_t, d_skip, h0):
        B, S, D = x.shape
        h = h0.astype(jnp.float32)
        ys = []
        for t in range(S):
            A = jnp.exp(dt[:, t][..., None] * a)
            u = (dt[:, t] * x[:, t])[..., None] * b_t[:, t][:, None, :]
            h = A * h + u
            ys.append(jnp.einsum("bdn,bn->bd", h, c_t[:, t]))
        return jnp.stack(ys, 1) + x * d_skip, h

    def _operands(self, B=2, S=24, D=5, N=3, seed=0):
        ks = jax.random.split(jax.random.PRNGKey(seed), 7)
        x = jax.random.normal(ks[0], (B, S, D))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, D)))
        a = -jnp.exp(jax.random.normal(ks[2], (D, N)) * 0.3)
        b_t = jax.random.normal(ks[3], (B, S, N))
        c_t = jax.random.normal(ks[4], (B, S, N))
        d_skip = jax.random.normal(ks[5], (D,))
        h0 = jax.random.normal(ks[6], (B, D, N))
        return x, dt, a, b_t, c_t, d_skip, h0

    @pytest.mark.parametrize("chunk", [1, 4, 8, 24, 100])
    def test_forward_matches_sequential(self, chunk):
        ops = self._operands()
        y1, h1 = selective_scan(*ops, chunk)
        y2, h2 = self._ref(*ops)
        np.testing.assert_allclose(y1, y2, atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(h1, h2, atol=1e-4, rtol=1e-4)

    @pytest.mark.slow
    def test_custom_vjp_matches_autodiff_reference(self):
        ops = self._operands(seed=5)

        def loss_fast(*args):
            y, h = selective_scan(*args, 8)
            return jnp.sum(jnp.sin(y)) + jnp.sum(jnp.cos(h))

        def loss_ref(*args):
            y, h = self._ref(*args)
            return jnp.sum(jnp.sin(y)) + jnp.sum(jnp.cos(h))

        g1 = jax.grad(loss_fast, argnums=tuple(range(7)))(*ops)
        g2 = jax.grad(loss_ref, argnums=tuple(range(7)))(*ops)
        for a, b in zip(g1, g2):
            np.testing.assert_allclose(a, b, atol=2e-4, rtol=2e-4)

    @settings(max_examples=8, deadline=None)
    @given(st.integers(min_value=1, max_value=30))
    def test_chunk_invariance(self, chunk):
        """Output must not depend on the chunking."""
        ops = self._operands(S=30, seed=9)
        y_ref, h_ref = selective_scan(*ops, 30)
        y, h = selective_scan(*ops, chunk)
        np.testing.assert_allclose(y, y_ref, atol=1e-4, rtol=1e-4)


class TestMoE:
    def _setup(self, n_tok=32, e=8, k=2, d=16, f=24):
        cfg = MoEConfig(n_experts=e, top_k=k, d_model=d, d_ff=f, capacity_factor=2.0)
        specs = moe_specs(cfg, w_init=normal_init(0.02), down_init=normal_init(0.02))
        params = init_params(specs, jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (2, n_tok // 2, d))
        return cfg, params, x

    @pytest.mark.slow
    def test_output_shape_and_grad(self):
        cfg, params, x = self._setup()
        y, aux = moe_forward(params, x, cfg)
        assert y.shape == x.shape
        assert float(aux) > 0
        g = jax.grad(lambda p: jnp.sum(moe_forward(p, x, cfg)[0] ** 2))(params)
        assert all(np.isfinite(np.asarray(l)).all() for l in jax.tree.leaves(g))

    def test_single_expert_equals_dense_mlp(self):
        """E=1, k=1 dropless MoE == plain MLP with that expert's weights."""
        cfg = MoEConfig(n_experts=1, top_k=1, d_model=8, d_ff=12, capacity_factor=4.0)
        specs = moe_specs(cfg, w_init=normal_init(0.1), down_init=normal_init(0.1))
        params = init_params(specs, jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 6, 8))
        y, _ = moe_forward(params, x, cfg)
        h = jnp.einsum("bsd,df->bsf", x, params["w_up"][0])
        gte = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, params["w_gate"][0])) * h
        y_ref = jnp.einsum("bsf,fd->bsd", gte, params["w_down"][0])
        np.testing.assert_allclose(y, y_ref, atol=1e-5, rtol=1e-4)

    def test_gate_renormalization(self):
        """Top-k gates renormalize to 1, so scaling router logits uniformly
        leaves the output unchanged."""
        cfg, params, x = self._setup()
        y1, _ = moe_forward(params, x, cfg)
        params2 = dict(params)
        y2, _ = moe_forward(params2, x, cfg)
        np.testing.assert_allclose(y1, y2, atol=1e-6)


class TestNorms:
    @settings(max_examples=20, deadline=None)
    @given(st.integers(min_value=1, max_value=8), st.integers(min_value=2, max_value=64))
    def test_rmsnorm_unit_rms(self, b, d):
        x = jax.random.normal(jax.random.PRNGKey(b * d), (b, d)) * 5
        y = rms_norm(x, jnp.ones((d,)))
        rms = jnp.sqrt(jnp.mean(jnp.square(y), axis=-1))
        np.testing.assert_allclose(rms, 1.0, atol=1e-2)

    def test_layernorm_zero_mean(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 32)) + 7.0
        y = layer_norm(x, jnp.ones((32,)), None)
        np.testing.assert_allclose(jnp.mean(y, axis=-1), 0.0, atol=1e-5)
