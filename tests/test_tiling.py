"""Edge cases of the strip tiling policy (`repro.kernels.tiling`).

The fit math is the load-bearing half of the VMEM contract the static
analyzer (`repro.analysis.kernelcheck`) re-derives from jaxprs, so the
boundary behavior — exact-budget reductions, the max(1, ...) cap, itemsize
threading, pad/trim round-trips — gets pinned here rather than observed
incidentally through the kernel tests.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.tiling import (COMPUTE_ITEMSIZE, VMEM_BUDGET,
                                  fit_strip_block, pad_kept, strip_fits,
                                  strip_grid, trim_kept)


class TestStripFits:
    def test_exact_budget_fits(self):
        n_bufs = 4  # divides the power-of-two budget exactly
        red = VMEM_BUDGET // (COMPUTE_ITEMSIZE * n_bufs)
        assert red * COMPUTE_ITEMSIZE * n_bufs == VMEM_BUDGET
        assert strip_fits(red, n_bufs)

    def test_one_past_budget_does_not_fit(self):
        n_bufs = 4
        red = VMEM_BUDGET // (COMPUTE_ITEMSIZE * n_bufs)
        assert not strip_fits(red + 1, n_bufs)

    def test_charges_compute_itemsize_not_storage(self):
        # A reduction that fits at bf16 storage width but not at the f32
        # compute width must NOT fit: kernels cast to f32 on load.
        n_bufs = 4
        red = VMEM_BUDGET // (2 * n_bufs)  # fits at itemsize=2 exactly
        assert strip_fits(red, n_bufs, itemsize=2)
        assert not strip_fits(red, n_bufs)

    def test_batch_extent_is_irrelevant(self):
        # Fitting is per-instance: only the reduction extent matters, the
        # batch dim rides on the grid. (Guards against someone "fixing" the
        # signature to take shapes.)
        assert strip_fits(1024, 6) == strip_fits(1024, 6)


class TestFitStripBlock:
    def test_exact_boundary_keeps_requested_block(self):
        # cap = VMEM_BUDGET / (red * 4 * n_bufs) lands exactly on the
        # requested block: no shrink.
        n_bufs, block = 4, 8
        red = VMEM_BUDGET // (COMPUTE_ITEMSIZE * n_bufs * block)
        assert fit_strip_block(red, block, kept_size=1024, n_bufs=n_bufs) == block

    def test_one_past_boundary_shrinks(self):
        n_bufs, block = 4, 8
        red = VMEM_BUDGET // (COMPUTE_ITEMSIZE * n_bufs * block)
        got = fit_strip_block(red + 1, block, kept_size=1024, n_bufs=n_bufs)
        assert got < block
        # and the shrunk tile actually fits
        assert got * (red + 1) * COMPUTE_ITEMSIZE * n_bufs <= VMEM_BUDGET

    def test_oversized_reduction_caps_at_one(self):
        # A single line over budget: cap = max(1, 0) = 1 — the function
        # never returns 0 (callers gate on strip_fits for the fallback).
        red = VMEM_BUDGET  # 4 * n_bufs * red >> budget
        assert fit_strip_block(red, 256, kept_size=1024, n_bufs=5) == 1

    def test_never_exceeds_kept(self):
        assert fit_strip_block(8, 256, kept_size=3, n_bufs=2) == 3

    def test_itemsize_threading(self):
        # Halving the itemsize doubles the admissible tile (until the
        # requested block caps it).
        n_bufs = 4
        red = VMEM_BUDGET // (COMPUTE_ITEMSIZE * n_bufs * 4)
        at4 = fit_strip_block(red, 1024, 1 << 20, n_bufs)
        at2 = fit_strip_block(red, 1024, 1 << 20, n_bufs, itemsize=2)
        assert at2 == 2 * at4


class TestStripGrid:
    @pytest.mark.parametrize("axis", [0, 1])
    def test_itemsize_reaches_fit(self, axis):
        # Same shape, wider itemsize -> no wider tile than the f32 plan.
        sg4 = strip_grid(2, 512, 2048, axis=axis, n_bufs=5, block=256)
        sg8 = strip_grid(2, 512, 2048, axis=axis, n_bufs=5, block=256,
                         itemsize=8)
        assert sg8.tile <= sg4.tile
        assert sg8.tile * sg8.n_red * 8 * 5 <= VMEM_BUDGET

    def test_minor_exact_boundary(self):
        n_bufs, block = 5, 4
        c = VMEM_BUDGET // (COMPUTE_ITEMSIZE * n_bufs * block)
        sg = strip_grid(1, 16, c, axis=1, n_bufs=n_bufs, block=block)
        assert sg.tile == block
        assert sg.grid == (1, 16 // block)

    @pytest.mark.parametrize("axis,shape", [(1, (2, 8, 128)), (0, (2, 128, 8))])
    def test_pad_trim_roundtrip_aligned(self, axis, shape):
        # Already tile-aligned kept axis: pad must be a no-op (same object
        # shape, identical values) and trim the exact inverse.
        sg = strip_grid(*shape, axis=axis, n_bufs=5, block=4)
        assert sg.kept % sg.tile == 0
        x = jnp.arange(np.prod(shape), dtype=jnp.float32).reshape(shape)
        padded = pad_kept(x, sg)
        assert padded.shape == x.shape
        np.testing.assert_array_equal(np.asarray(padded), np.asarray(x))
        np.testing.assert_array_equal(np.asarray(trim_kept(padded, sg)),
                                      np.asarray(x))

    @pytest.mark.parametrize("axis", [0, 1])
    def test_pad_trim_roundtrip_ragged(self, axis):
        shape = (1, 13, 128) if axis == 1 else (1, 128, 13)
        sg = strip_grid(*shape, axis=axis, n_bufs=5, block=4)
        x = jnp.arange(np.prod(shape), dtype=jnp.float32).reshape(shape)
        padded = pad_kept(x, sg)
        assert padded.shape[sg.kept_axis] % sg.tile == 0
        # reduction axis untouched
        red_ax = 2 if axis == 1 else 1
        assert padded.shape[red_ax] == x.shape[red_ax]
        np.testing.assert_array_equal(np.asarray(trim_kept(padded, sg)),
                                      np.asarray(x))
