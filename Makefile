PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

# Parallelize across cores when pytest-xdist is installed (requirements-dev);
# empty (serial) otherwise so the targets degrade gracefully.
XDIST := $(shell python -c "import xdist" 2>/dev/null && printf -- "-n auto")

.PHONY: test test-fast bench-quick bench-roofline ci

test:
	PYTHONPATH=$(PYTHONPATH) python -m pytest -x -q $(XDIST)

# Quick iteration loop: skip the slow-marked cases (multi-device subprocess
# tests, long trainer loops). CI (`make ci`) always runs the full suite.
test-fast:
	PYTHONPATH=$(PYTHONPATH) python -m pytest -x -q $(XDIST) -m "not slow"

bench-quick:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run --preset quick --only opt_speed
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run --preset quick --only opt_speed_tree

# Planner gate: the opt_speed_tree byte model over the full GPT-small leaf
# set must stay transpose-free (fails if any leaf regresses to a
# materialized-transpose plan). Analytic — safe and fast in interpret mode.
bench-roofline:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.opt_speed --check-roofline

ci:
	bash scripts/ci.sh
