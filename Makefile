# Every target delegates to scripts/ci.sh — the single source of truth the
# GitHub workflow calls too, so `make ci` and hosted CI cannot drift.

.PHONY: lint analyze test test-fast bench-quick bench bench-roofline bench-serve serve-drill fault-drill ci

lint:
	bash scripts/ci.sh lint

# Static contract checker (repro.analysis): kernel buffer/VMEM/dtype and
# golden-signature checks, grid-race detection, sharding-plan geometry over
# the config zoo, guarded-step trace stability, and the RPR lint rules —
# device-free and fast, the gate between lint and the test tiers.
analyze:
	bash scripts/ci.sh analyze

test:
	bash scripts/ci.sh test-full

# Quick iteration loop: skip the slow-marked cases (multi-device subprocess
# tests, long trainer loops). CI (`make ci`) always runs the full suite.
test-fast:
	bash scripts/ci.sh test-fast

bench-quick:
	bash scripts/ci.sh bench-quick

# Full quick-preset sweep (what the GitHub `bench` job runs + uploads).
bench:
	bash scripts/ci.sh bench

# Analytic planner gates: (1) the opt_speed_tree byte model over the full
# GPT-small leaf set must stay transpose-free; (2) under shard_map on the
# production (data=16, model=16) mesh, every transpose-free leaf must stream
# per-shard bytes <= single-device bytes / min(shard counts). Fast and safe
# in interpret mode — nothing executes, only the planners run.
bench-roofline:
	bash scripts/ci.sh bench-roofline

# Serving fast-path gate: paged KV pool/scheduler/parity test suite + the
# engine bench (O(1) pallas launches per decode step, chunked prefill >= 4x
# fewer device steps than token-by-token, greedy output token-identical to
# the legacy generate() oracle).
bench-serve:
	bash scripts/ci.sh bench-serve

# Serving fault-tolerance gate: the serving fault/SLO test suite + the chaos
# drill (a run injected with kernel failures, poisoned logits, a pool squeeze
# and a deadline-blowing stall must drain with greedy parity on unpoisoned
# requests, zero page leaks, and every injection visible in ServeMetrics).
serve-drill:
	bash scripts/ci.sh serve-drill

# Resilience gate: fault-injection test suite + the end-to-end drill (an
# injected gpt_small run must complete within 2% of the clean run's eval
# loss with every injection visible in the guard counters).
fault-drill:
	bash scripts/ci.sh fault-drill

ci:
	bash scripts/ci.sh
