PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test bench-quick ci

test:
	PYTHONPATH=$(PYTHONPATH) python -m pytest -x -q

bench-quick:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run --preset quick --only opt_speed
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run --preset quick --only opt_speed_tree

ci:
	bash scripts/ci.sh
